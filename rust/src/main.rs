//! `avo` — the Layer-3 coordinator CLI.
//!
//! Subcommands (hand-rolled parser; clap is not vendored offline):
//!   evolve       run the AVO evolution loop (the paper's main experiment)
//!                on any registered workload, optionally as an N-island
//!                archipelago and/or over remote eval workers
//!   eval-worker  host a remote evaluation worker: serve evaluate_batch
//!                requests over TCP for a coordinator running with
//!                --remote-workers / --connect (see avo::eval::remote)
//!   monitor      attach to a running evolve's live metrics endpoint
//!                (--metrics-addr) and stream one-line status snapshots
//!   journal-merge  merge JSONL event journals into one stable-ordered
//!                stream (per-island `seq` lanes), so multi-worker steady
//!                runs are diffable
//!   serve        host a run job queue: accept submit/status/cancel of
//!                named evolve runs over the same length-prefixed JSON
//!                framing the eval workers speak (see avo::supervisor::serve)
//!   job          one-shot client for a running `avo serve` (submit,
//!                status, cancel, archive, shutdown)
//!   transfer     adapt an evolved lineage to another workload (§4.3
//!                generalized: gqa:<kv>, decode:<batch>, mha)
//!   compare      AVO vs single-turn vs fixed-pipeline at equal budget
//!   show         print a lineage file (versions, scores, sources)
//!   profile      print the profiler report for a genome on one config
//!
//! Examples:
//!   avo evolve --seed 42 --commits 40 --out runs/mha
//!   avo evolve --workload decode:32 --commits 12 --out runs/decode
//!   avo evolve --islands 4 --migration broadcast_best --migrate-every 3
//!   avo evolve --islands 3 --operators avo,single_turn,fixed_pipeline
//!   avo evolve --warm-start runs/mha --out runs/mha2   # reuse evaluations
//!   avo evolve --adaptive-migration --eval-cache-max-entries 100000
//!   avo evolve --remote-workers 4                      # spawn local workers
//!   avo eval-worker --workload mha --listen 0.0.0.0:7654   # on each machine
//!   avo evolve --connect hostA:7654,hostB:7654         # attach to them
//!   avo eval-worker --listen 0.0.0.0:7654 --remote-secret t0ken
//!   avo evolve --connect hostA:7654 --remote-secret t0ken  # authenticated
//!   avo evolve --journal runs/mha/journal.jsonl --metrics-addr 127.0.0.1:7655
//!   avo monitor 127.0.0.1:7655                         # watch it live
//!   avo journal-merge runs/a/journal.jsonl runs/b/journal.jsonl
//!   avo evolve --checkpoint-dir runs/mha/ckpt            # crash-safe ledger
//!   avo evolve --resume runs/mha/ckpt                    # continue it
//!   avo serve --listen 127.0.0.1:7700                    # run job queue
//!   avo job 127.0.0.1:7700 submit nightly --config runs/mha.cfg
//!   avo job 127.0.0.1:7700 status nightly
//!   avo evolve --config runs/mha.cfg
//!   avo transfer --lineage runs/mha/lineage.json --workload gqa:4
//!   avo transfer --lineage runs/mha/lineage.json --workload decode:32
//!   avo compare --budget 240
//!   avo show --lineage runs/mha/lineage.json

use std::path::PathBuf;

use avo::coordinator::{config::OperatorKind, EvolutionDriver, RunConfig, SchedulingMode};
use avo::evolution::Lineage;
use avo::islands::MigrationPolicy;
use avo::kernelspec::KernelSpec;
use avo::score::{mha_suite, BenchConfig, Evaluator};
use avo::sim::profile::profile;

type CliError = Box<dyn std::error::Error>;

fn usage() -> ! {
    eprintln!(
        "usage: avo <evolve|eval-worker|monitor|journal-merge|serve|job|transfer|compare|show|\
         profile> [flags]\n\
         \n\
         evolve   --workload {} (default mha)\n\
         \u{20}         --seed N --commits N --steps N --operator avo|single_turn|pes\n\
         \u{20}         --operators OP[,OP...]  (heterogeneous islands, round-robin)\n\
         \u{20}         --islands N --migration ring|broadcast_best|random_pairs\n\
         \u{20}         --migrate-every K --island-workers N\n\
         \u{20}         --barrier | --steady-state  (island scheduling mode;\n\
         \u{20}          barrier epochs are the byte-pinned default, steady-state\n\
         \u{20}          lets islands free-run with mailbox migration)\n\
         \u{20}         --mailbox-capacity N  (steady-state migrant inbox bound,\n\
         \u{20}          oldest dropped on overflow; default 8)\n\
         \u{20}         --dispatch-plane  (coalesce cross-island steady-state\n\
         \u{20}          eval batches before the backend stack; engages with\n\
         \u{20}          >1 island and >1 island worker)\n\
         \u{20}         --coalesce-window-evals N  (max specs per coalesced\n\
         \u{20}          batch; default 64)\n\
         \u{20}         --remote-workers N  (self-spawn N eval-worker processes)\n\
         \u{20}         --connect HOST:PORT[,HOST:PORT...]  (attach external workers)\n\
         \u{20}         --adaptive-migration --adaptive-stall-epochs K\n\
         \u{20}         --checkpoint-dir DIR  (durable run ledger: commit the\n\
         \u{20}          full search state after every generation, atomically)\n\
         \u{20}         --resume DIR  (continue an interrupted checkpointed run\n\
         \u{20}          byte-identically; the snapshot's saved search config\n\
         \u{20}          wins, so no flags need repeating)\n\
         \u{20}         --halt-after-checkpoints N  (stop after N more ledger\n\
         \u{20}          commits; the kill-and-resume test's SIGKILL stand-in)\n\
         \u{20}         --warm-start DIR  (reuse a prior run's eval cache)\n\
         \u{20}         --eval-cache-max-entries N  --speculative-repair\n\
         \u{20}         --lookahead K  (batch K candidate edits per direction)\n\
         \u{20}         --trace-out FILE  (agent stage/batching trace as JSON)\n\
         \u{20}         --trace-deterministic  (omit wall-clock timings from\n\
         \u{20}          the trace, journal, and any other volatile fields)\n\
         \u{20}         --journal FILE  (JSONL event journal, crash-safe)\n\
         \u{20}         --metrics-addr HOST:PORT  (live metrics endpoint;\n\
         \u{20}          port 0 picks a free port, announced on stdout)\n\
         \u{20}         --metrics-linger-ms N --remote-read-timeout-ms N\n\
         \u{20}         --remote-secret TOKEN  (shared handshake secret; env\n\
         \u{20}          AVO_REMOTE_SECRET is the fallback on both sides)\n\
         \u{20}         --no-remote-gossip  (disable worker cache-delta gossip)\n\
         \u{20}         --remote-reattach-cooldown-ms N  (dead-endpoint retry\n\
         \u{20}          throttle; default 500)\n\
         \u{20}         --config FILE --out DIR\n\
         eval-worker --workload SPEC --listen ADDR (default 127.0.0.1:0)\n\
         \u{20}         --once --eval-workers N --fail-after N --stall-after N\n\
         \u{20}         --remote-secret TOKEN  (or env AVO_REMOTE_SECRET)\n\
         monitor  ADDR [--once] [--json] [--interval-ms N] [--retry-ms N]\n\
         journal-merge FILE [FILE...] [--out FILE] [--strict]  (stable-ordered\n\
         \u{20}         merge; torn trailing lines are dropped with a\n\
         \u{20}         journal_torn_tail warning, nonzero exit under --strict)\n\
         serve    [--listen ADDR]  (run job queue; default 127.0.0.1:0,\n\
         \u{20}         announced as AVO_SERVE_LISTENING <addr>)\n\
         job      ADDR submit NAME --config FILE [--metrics]\n\
         \u{20}         | status NAME | cancel NAME\n\
         \u{20}         | archive NAME [--out FILE] | shutdown\n\
         transfer --lineage FILE --workload SPEC (or --kv-heads 4|8)\n\
         \u{20}         --seed N --out DIR\n\
         compare  --budget N --seed N\n\
         show     --lineage FILE [--sources]\n\
         profile  --causal --seq N",
        avo::workload::KNOWN.join("|")
    );
    std::process::exit(2)
}

struct Flags(Vec<String>);

impl Flags {
    fn get(&self, name: &str) -> Option<&str> {
        self.0
            .iter()
            .position(|a| a == name)
            .and_then(|i| self.0.get(i + 1))
            .map(|s| s.as_str())
    }

    fn has(&self, name: &str) -> bool {
        self.0.iter().any(|a| a == name)
    }

    /// Parse a flag's value; a malformed value is an error, not a silent
    /// fall-through to the default.
    fn parse_strict<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, CliError>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| format!("{name}: invalid value '{v}': {e}").into()),
        }
    }
}

/// Shared handshake secret: `--remote-secret` wins, env `AVO_REMOTE_SECRET`
/// is the fallback (and how self-spawned workers inherit it without the
/// secret showing up in process listings).
fn remote_secret(flags: &Flags) -> Option<String> {
    flags
        .get("--remote-secret")
        .map(str::to_string)
        .or_else(|| std::env::var("AVO_REMOTE_SECRET").ok().filter(|s| !s.is_empty()))
}

fn main() -> Result<(), CliError> {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
    }
    let cmd = args.remove(0);
    let flags = Flags(args);

    match cmd.as_str() {
        "evolve" => {
            let mut cfg = match flags.get("--config") {
                Some(path) => RunConfig::load(std::path::Path::new(path))?,
                None => RunConfig::default(),
            };
            if let Some(s) = flags.parse_strict("--seed")? {
                cfg.seed = s;
            }
            if let Some(c) = flags.parse_strict("--commits")? {
                cfg.target_commits = c;
            }
            if let Some(s) = flags.parse_strict("--steps")? {
                cfg.max_steps = s;
            }
            if let Some(op) = flags.get("--operator") {
                cfg.operator = op.parse::<OperatorKind>()?;
            }
            if let Some(ops) = flags.get("--operators") {
                cfg.operator_mix = avo::coordinator::config::parse_operator_list(ops)?;
            }
            if let Some(w) = flags.get("--workload") {
                avo::workload::parse(w)?; // validate against the registry
                cfg.workload = w.to_string();
            }
            if let Some(n) = flags.parse_strict("--islands")? {
                cfg.topology.islands = n;
            }
            if let Some(m) = flags.get("--migration") {
                cfg.topology.migration = m.parse::<MigrationPolicy>()?;
            }
            if let Some(k) = flags.parse_strict("--migrate-every")? {
                cfg.topology.migrate_every = k;
            }
            if let Some(w) = flags.parse_strict("--island-workers")? {
                cfg.topology.workers = w;
            }
            if let Some(n) = flags.parse_strict("--remote-workers")? {
                cfg.topology.remote.workers = n;
            }
            if let Some(list) = flags.get("--connect") {
                cfg.topology.remote.connect =
                    avo::coordinator::config::parse_connect_list(list)?;
            }
            if let Some(dir) = flags.get("--warm-start") {
                cfg.warm_start = Some(PathBuf::from(dir));
            }
            if let Some(n) = flags.parse_strict("--eval-cache-max-entries")? {
                cfg.eval_cache_max_entries = Some(n);
            }
            if flags.has("--speculative-repair") {
                cfg.agent.speculative_repair = true;
            }
            if let Some(k) = flags.parse_strict::<usize>("--lookahead")? {
                if k == 0 {
                    return Err("--lookahead must be >= 1".into());
                }
                cfg.agent.lookahead = k;
            }
            if flags.has("--adaptive-migration") {
                cfg.topology.adaptive_migration = true;
            }
            if let Some(k) = flags.parse_strict("--adaptive-stall-epochs")? {
                cfg.topology.adaptive_stall_epochs = k;
            }
            if flags.has("--steady-state") {
                if flags.has("--barrier") {
                    return Err("--steady-state and --barrier are mutually exclusive".into());
                }
                cfg.topology.scheduling = SchedulingMode::SteadyState;
            } else if flags.has("--barrier") {
                cfg.topology.scheduling = SchedulingMode::Barrier;
            }
            if let Some(c) = flags.parse_strict::<usize>("--mailbox-capacity")? {
                cfg.topology.mailbox_capacity = c.max(1);
            }
            if flags.has("--dispatch-plane") {
                cfg.topology.dispatch_plane = true;
            }
            if let Some(w) = flags.parse_strict::<usize>("--coalesce-window-evals")? {
                cfg.topology.coalesce_window_evals = w.max(1);
            }
            if let Some(path) = flags.get("--journal") {
                cfg.telemetry.journal = Some(PathBuf::from(path));
            }
            if let Some(addr) = flags.get("--metrics-addr") {
                cfg.telemetry.metrics_addr = Some(addr.to_string());
            }
            if let Some(ms) = flags.parse_strict("--metrics-linger-ms")? {
                cfg.telemetry.linger_ms = ms;
            }
            if let Some(ms) = flags.parse_strict("--remote-read-timeout-ms")? {
                cfg.topology.remote.read_timeout_ms = ms;
            }
            if let Some(secret) = remote_secret(&flags) {
                cfg.topology.remote.secret = Some(secret);
            }
            if flags.has("--no-remote-gossip") {
                cfg.topology.remote.gossip = false;
            }
            if let Some(ms) = flags.parse_strict("--remote-reattach-cooldown-ms")? {
                cfg.topology.remote.reattach_cooldown_ms = ms;
            }
            if let Some(dir) = flags.get("--checkpoint-dir") {
                cfg.checkpoint_dir = Some(PathBuf::from(dir));
            }
            if let Some(dir) = flags.get("--resume") {
                if flags.has("--checkpoint-dir") {
                    return Err(
                        "--resume DIR already names the checkpoint dir; drop --checkpoint-dir"
                            .into(),
                    );
                }
                // The overlay runs after every other flag so the
                // snapshot's saved search config wins — any mismatched
                // search flag would diverge from (or be rejected against)
                // the snapshot anyway.  Output paths, telemetry, and
                // worker counts stay CLI-controlled.
                let dir = PathBuf::from(dir);
                avo::supervisor::checkpoint::overlay_config(&dir, &mut cfg)
                    .map_err(|e| format!("--resume: {e}"))?;
                cfg.checkpoint_dir = Some(dir);
                cfg.resume = true;
            }
            if let Some(n) = flags.parse_strict("--halt-after-checkpoints")? {
                if cfg.checkpoint_dir.is_none() {
                    return Err(
                        "--halt-after-checkpoints requires --checkpoint-dir or --resume".into()
                    );
                }
                cfg.halt_after_checkpoints = Some(n);
            }
            let out_dir = flags.get("--out").map(PathBuf::from);
            if let Some(dir) = &out_dir {
                std::fs::create_dir_all(dir)?;
                cfg.lineage_path = Some(dir.join("lineage.json"));
                cfg.eval_cache_path = Some(dir.join(avo::eval::CACHE_FILE));
            }
            // Validate the warm-start cache (whether it came from the
            // --warm-start flag or a `warm_start =` config key) up front,
            // so a typo'd directory / corrupt file / stale fingerprint is
            // a clean CLI error instead of a mid-run panic.
            if let Some(dir) = &cfg.warm_start {
                avo::eval::persist::validate(dir, avo::EvalBackend::cache_tag(&cfg.evaluator()))
                    .map_err(|e| format!("warm-start: {e}"))?;
            }
            let trace_out = flags.get("--trace-out").map(PathBuf::from);
            let trace_deterministic = flags.has("--trace-deterministic");
            // One flag governs every volatile field: the agent trace AND
            // the telemetry journal drop wall-clock under it, so same-seed
            // runs produce byte-identical artifacts across the board.
            cfg.telemetry.deterministic = trace_deterministic;
            let journal_path = cfg.telemetry.journal.clone();
            let suite = cfg.evaluator().suite;
            let report = EvolutionDriver::new(cfg).run();
            println!("{}", report.summary());
            if let Some(path) = &trace_out {
                if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
                    std::fs::create_dir_all(dir)?;
                }
                std::fs::write(path, report.trace_json(trace_deterministic).pretty())?;
                println!("wrote agent trace to {}", path.display());
            }
            if report.islands.len() > 1 {
                for isl in &report.islands {
                    println!(
                        "  island {} [{}]: {} commits, best {:.1} TFLOPS, {} steps, \
                         {} migrants in ({} accepted)",
                        isl.id,
                        isl.operator,
                        isl.lineage.len(),
                        isl.lineage.best_geomean(),
                        isl.steps,
                        isl.metrics.counter("migrants_received"),
                        isl.metrics.counter("migrants_accepted"),
                    );
                }
            }
            let (h, m) = (
                report.metrics.counter("eval_cache_hits"),
                report.metrics.counter("eval_cache_misses"),
            );
            println!(
                "  eval cache: {h} hits / {m} misses ({:.0}% deduplicated)",
                100.0 * h as f64 / (h + m).max(1) as f64
            );
            let warm = report.metrics.counter("eval_cache_warm_entries");
            if warm > 0 {
                println!("  warm-start: {warm} cached evaluations loaded from prior run");
            }
            for note in &report.interventions {
                println!("  supervisor: {note}");
            }
            if let Some(path) = &journal_path {
                println!("  journal: {}", path.display());
            }
            println!("{}", report.metrics.report());
            if let Some(dir) = &out_dir {
                // Only regimes the suite actually contains: a decode run
                // has no causal cells, and an all-zero trajectory file
                // would read as a broken run.  An absent regime's file is
                // removed so a reused --out directory can't serve a stale
                // trajectory from a different workload.
                let mut artifacts = vec!["lineage"];
                if suite.iter().any(|c| c.causal) {
                    std::fs::write(
                        dir.join("trajectory_causal.json"),
                        report.lineage.trajectory_json(true).pretty(),
                    )?;
                    artifacts.push("causal trajectory");
                } else {
                    std::fs::remove_file(dir.join("trajectory_causal.json")).ok();
                }
                if suite.iter().any(|c| !c.causal) {
                    std::fs::write(
                        dir.join("trajectory_noncausal.json"),
                        report.lineage.trajectory_json(false).pretty(),
                    )?;
                    artifacts.push("non-causal trajectory");
                } else {
                    std::fs::remove_file(dir.join("trajectory_noncausal.json")).ok();
                }
                artifacts.push("eval cache");
                println!("wrote {} to {}", artifacts.join(" + "), dir.display());
            }
        }
        "eval-worker" => {
            // The worker process the coordinator self-spawns for
            // --remote-workers (and the one you run by hand on each
            // machine for --connect).  Body lives in avo::eval::remote.
            let mut opts = avo::eval::remote::WorkerOptions::default();
            if let Some(w) = flags.get("--workload") {
                avo::workload::parse(w)?; // validate against the registry
                opts.workload = w.to_string();
            }
            if let Some(l) = flags.get("--listen") {
                opts.listen = l.to_string();
            }
            opts.once = flags.has("--once");
            opts.fail_after = flags.parse_strict("--fail-after")?;
            opts.stall_after = flags.parse_strict("--stall-after")?;
            if let Some(n) = flags.parse_strict("--eval-workers")? {
                opts.eval_workers = n;
            }
            opts.secret = remote_secret(&flags);
            avo::eval::remote::run_worker(&opts)?;
        }
        "journal-merge" => {
            // Positional args are journal paths; --out redirects the
            // merged stream from stdout to a file.
            let out = flags.get("--out").map(PathBuf::from);
            let strict = flags.has("--strict");
            let mut paths = Vec::new();
            let mut skip = false;
            for a in &flags.0 {
                if skip {
                    skip = false;
                    continue;
                }
                if a == "--out" {
                    skip = true;
                    continue;
                }
                if a == "--strict" {
                    continue;
                }
                if a.starts_with("--") {
                    return Err(format!("journal-merge: unknown flag {a}").into());
                }
                paths.push(PathBuf::from(a));
            }
            if paths.is_empty() {
                usage();
            }
            let (merged, torn) = avo::telemetry::merge_journals_counting(&paths)?;
            if torn > 0 {
                // A torn tail is normal after a crash mid-append; surface
                // it instead of silently shortening the stream.
                eprintln!("journal_torn_tail: {torn}");
            }
            match &out {
                Some(path) => {
                    if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
                        std::fs::create_dir_all(dir)?;
                    }
                    let mut body = merged.join("\n");
                    if !body.is_empty() {
                        body.push('\n');
                    }
                    std::fs::write(path, body)?;
                    eprintln!(
                        "merged {} journal(s), {} events -> {}",
                        paths.len(),
                        merged.len(),
                        path.display()
                    );
                }
                None => {
                    for line in &merged {
                        println!("{line}");
                    }
                }
            }
            if strict && torn > 0 {
                return Err(
                    format!("journal-merge: dropped {torn} torn line(s) (--strict)").into()
                );
            }
        }
        "serve" => {
            // The run job queue: one frame per connection, verbs
            // submit/status/cancel/archive/shutdown (see
            // avo::supervisor::serve for the wire table).  Blocks until a
            // shutdown frame arrives.
            let addr = flags.get("--listen").unwrap_or("127.0.0.1:0");
            let bound = avo::telemetry::AddrCell::default();
            avo::supervisor::serve::serve(addr, &bound)?;
        }
        "job" => {
            // One-shot client for a running `avo serve`.
            use avo::json::Json;
            let addr = flags
                .0
                .first()
                .filter(|a| !a.starts_with("--"))
                .cloned()
                .unwrap_or_else(|| usage());
            let verb = flags.0.get(1).cloned().unwrap_or_else(|| usage());
            let name = flags
                .0
                .get(2)
                .filter(|a| !a.starts_with("--"))
                .cloned();
            let named = |verb: &str, name: Option<String>| -> Result<Json, CliError> {
                let name = name.ok_or_else(|| format!("job {verb} requires a job name"))?;
                Ok(Json::obj([
                    ("type", Json::Str(verb.to_string())),
                    ("name", Json::Str(name)),
                ]))
            };
            let msg = match verb.as_str() {
                "submit" => {
                    let name =
                        name.ok_or_else(|| "job submit requires a job name".to_string())?;
                    let path = flags
                        .get("--config")
                        .ok_or_else(|| "job submit requires --config FILE".to_string())?;
                    let config = std::fs::read_to_string(path)
                        .map_err(|e| format!("{path}: {e}"))?;
                    let mut fields = vec![
                        ("type", Json::Str("submit".to_string())),
                        ("name", Json::Str(name)),
                        ("config", Json::Str(config)),
                    ];
                    if flags.has("--metrics") {
                        fields.push(("metrics", Json::Bool(true)));
                    }
                    Json::obj(fields)
                }
                "status" | "cancel" | "archive" => named(&verb, name)?,
                "shutdown" => Json::obj([("type", Json::Str("shutdown".to_string()))]),
                _ => usage(),
            };
            let reply = avo::supervisor::serve::request(&addr, &msg)?;
            if reply.get("type").and_then(Json::as_str) == Some("error") {
                let message = reply
                    .get("message")
                    .and_then(Json::as_str)
                    .unwrap_or("unknown server error");
                return Err(format!("job {verb}: {message}").into());
            }
            // `archive --out FILE` saves the archive body (a loadable
            // lineage file); everything else prints the reply frame.
            if verb == "archive" {
                if let (Some(path), Some(archive)) =
                    (flags.get("--out"), reply.get("archive"))
                {
                    let path = PathBuf::from(path);
                    if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
                        std::fs::create_dir_all(dir)?;
                    }
                    std::fs::write(&path, archive.pretty())?;
                    println!("wrote archive to {}", path.display());
                } else {
                    println!("{}", reply.pretty());
                }
            } else {
                println!("{}", reply.pretty());
            }
        }
        "monitor" => {
            // First positional argument is the endpoint address (what the
            // run printed as AVO_METRICS_LISTENING <addr>).
            let addr = flags
                .0
                .first()
                .filter(|a| !a.starts_with("--"))
                .cloned()
                .unwrap_or_else(|| usage());
            let mut opts = avo::telemetry::MonitorOptions {
                once: flags.has("--once"),
                json: flags.has("--json"),
                ..avo::telemetry::MonitorOptions::default()
            };
            if let Some(ms) = flags.parse_strict("--interval-ms")? {
                opts.interval_ms = ms;
            }
            if let Some(ms) = flags.parse_strict("--retry-ms")? {
                opts.retry_ms = ms;
            }
            avo::telemetry::run_monitor(&addr, &opts)?;
        }
        "transfer" => {
            let lineage_path = flags.get("--lineage").unwrap_or_else(|| usage());
            // Target workload: --workload SPEC, or the legacy --kv-heads
            // shorthand for the paper's GQA transfer.
            let (target, out_name) = match flags.get("--workload") {
                Some(w) => {
                    if flags.has("--kv-heads") {
                        return Err(
                            "--workload and --kv-heads are mutually exclusive \
                             (--kv-heads N is shorthand for --workload gqa:N)"
                                .into(),
                        );
                    }
                    avo::workload::parse(w)?;
                    (w.to_string(), format!("{}_lineage.json", w.replace(':', "_")))
                }
                None => {
                    let kv: u32 = flags.parse_strict("--kv-heads")?.unwrap_or(4);
                    // The legacy shorthand keeps its legacy output name so
                    // scripts consuming gqa_lineage.json keep working.
                    (format!("gqa:{kv}"), "gqa_lineage.json".to_string())
                }
            };
            let lineage = Lineage::load(std::path::Path::new(lineage_path))?;
            let evolved = lineage.best().expect("empty lineage").spec.clone();
            let mut cfg = RunConfig::default();
            if let Some(s) = flags.parse_strict("--seed")? {
                cfg.seed = s;
            }
            if let Some(dir) = flags.get("--out") {
                std::fs::create_dir_all(dir)?;
                cfg.lineage_path = Some(PathBuf::from(dir).join(out_name));
            }
            let report = EvolutionDriver::new(cfg).transfer_to(&target, evolved)?;
            println!("transfer onto {target}: {}", report.summary());
        }
        "compare" => {
            let budget: usize = flags.parse_strict("--budget")?.unwrap_or(240);
            let seed: u64 = flags.parse_strict("--seed")?.unwrap_or(42);
            for op in [
                OperatorKind::Avo,
                OperatorKind::SingleTurn,
                OperatorKind::FixedPipeline,
            ] {
                let cfg = RunConfig {
                    operator: op,
                    seed,
                    target_commits: usize::MAX / 2,
                    max_steps: budget,
                    ..RunConfig::default()
                };
                let report = EvolutionDriver::new(cfg).run();
                println!("{op:?}: {}", report.summary());
            }
        }
        "show" => {
            let path = flags.get("--lineage").unwrap_or_else(|| usage());
            let lineage = Lineage::load(std::path::Path::new(path))?;
            for c in lineage.versions() {
                println!(
                    "v{:<3} {:016x} geomean {:8.1}  {}",
                    c.step,
                    c.id.0,
                    c.score.geomean(),
                    c.message
                );
                if flags.has("--sources") {
                    println!("{}", c.source);
                }
            }
        }
        "profile" => {
            let causal = flags.has("--causal");
            let seq: u32 = flags.parse_strict("--seq")?.unwrap_or(32768);
            let eval = Evaluator::new(mha_suite());
            let cfg = BenchConfig::mha((32768 / seq).max(1), seq, causal);
            let spec = KernelSpec::naive();
            println!("{}", profile(&eval.report(&spec, &cfg)).to_text());
            let evolved = avo::baselines::evolved_genome();
            println!("{}", profile(&eval.report(&evolved, &cfg)).to_text());
        }
        _ => usage(),
    }
    Ok(())
}
