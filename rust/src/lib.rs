//! # AVO — Agentic Variation Operators for Autonomous Evolutionary Search
//!
//! Full-system reproduction of the AVO paper (CS.LG 2026) on the
//! Rust + JAX + Pallas three-layer stack:
//!
//! * **Layer 3 (this crate)** — the paper's contribution: an evolutionary
//!   search coordinator whose variation operator is an autonomous agent.
//!   The agent runtime ([`agent::stages`]) is a staged, introspectable
//!   pipeline — **Consult** (profile the lineage, [`evolution`], and fold
//!   bottlenecks into direction weights), **Propose** (knowledge-base
//!   retrieval ([`knowledge`]), crossover, migrants — up to `--lookahead k`
//!   edits batched per direction), **Repair** (the ranked-repair table +
//!   speculative batching), **Critique** (refine-while-improving,
//!   score-delta triage, hazard classification), and **Verify** (the
//!   Update rule) — threaded through a shared `AgentContext` over a typed
//!   kernel genome ([`kernelspec::KernelSpec`]) and the scoring function
//!   ([`score`]).  [`agent::AvoAgent`] is the full pipeline; the Figure-1
//!   baselines are degenerate pipelines of the same stages; every step
//!   emits an [`agent::AgentTrace`] (stage timings, batch widths,
//!   accept/reject reasons) surfaced per island and per run (`avo evolve
//!   --trace-out`).  Runs are supervised against stalls and unproductive
//!   cycles ([`supervisor`]).
//! * **Workloads** ([`workload`]) — the scenario seam: a [`Workload`]
//!   bundles the benchmark suite, correctness regimes, knowledge-base
//!   shard, phase schedule, seed genome, baseline anchors, and a
//!   cache-isolating tag.  Registered scenarios: `mha` and `gqa:<kv>`
//!   (byte-for-byte the paper's runs) and `decode:<batch>` (single-query
//!   decode over a batched KV cache, priced by a split-KV path in the
//!   simulator).  `EvolutionDriver::transfer_to` adapts an evolved genome
//!   across workloads, generalizing the paper's §4.3 GQA transfer.
//! * **Scale-out** — two orthogonal tiers behind one `SearchTopology`
//!   config.  *Thread tier* ([`islands`]): N concurrent lineages with
//!   per-island PRNG streams and elite migration (ring / broadcast-best /
//!   random pairs, with optional adaptive intervals for stalled islands);
//!   the paper's sequential regime is the one-island special case.  Two
//!   scheduling modes ([`coordinator::SchedulingMode`]): **barrier** (the
//!   default) steps islands under epoch barriers — archives are
//!   byte-identical at every worker count — and **steady-state**
//!   (`--steady-state`, [`islands::steady`]) lets islands free-run on a
//!   shared worker pool with elites flowing through bounded,
//!   oldest-dropped [`islands::MigrantMailbox`]es, so one slow island (or
//!   one slow eval round) never stalls the rest; seed-deterministic with
//!   `--island-workers 1`.
//!   *Process tier* ([`eval::remote`]): `avo eval-worker` processes absorb
//!   `evaluate_batch` traffic over a zero-dependency length-prefixed JSON
//!   TCP protocol — self-spawned (`--remote-workers <n>`) or attached
//!   across machines (`--connect host:port,...`), handshake-checked on
//!   `suite_tag ^ MachineSpec::fingerprint()` (optionally authenticated
//!   with a shared secret, `--remote-secret` / `AVO_REMOTE_SECRET`), with
//!   in-flight requeue when a worker dies mid-batch and a work-stealing
//!   dispatch queue (oversplit chunks, home-worker affinity) that keeps
//!   fast workers fed while a straggler finishes.  The fleet is also a
//!   distributed eval-cache fabric: each worker hosts a `Cached<Sim>`
//!   stack, fresh entries gossip back piggybacked on `scores` frames and
//!   fan out to siblings on later `eval` frames (so a spec computed
//!   anywhere is never re-simulated), and a worker that restarts on the
//!   same endpoint is re-attached mid-run and re-warmed from the
//!   coordinator's ledger.  Remote archives are byte-identical to
//!   in-process archives (pinned by `rust/tests/remote_eval.rs`, including
//!   a mid-run worker kill, a mid-run re-attach, and a protocol-1 worker
//!   in a mixed fleet; `benches/remote_fabric.rs` gates the fleet-dedup
//!   win, `benches/archipelago_steadystate.rs` the idle-fraction win
//!   under injected latency skew).  The two tiers meet in the *dispatch
//!   plane* ([`eval::DispatchPlane`], `--dispatch-plane`): steady-state
//!   islands submit their narrow eval batches as tickets into a global
//!   coalescing queue, a dispatcher merges them cross-island into
//!   full-width batches for the stack below — so the work-stealing queue
//!   sees fleet-wide batches instead of per-island slivers — and each
//!   island gets back exactly its own scores in submission order
//!   (`benches/dispatch_plane.rs` gates the chunk-widening and wall-clock
//!   wins over a skewed fleet; how long an underfilled dispatch lingers
//!   for stragglers adapts to the observed dispatch RTT p50 — eager when
//!   the fleet is keeping up, wider when saturated).  Worker-side caches
//!   inherit the coordinator's `--eval-cache-max-entries` bound through
//!   the v2 handshake; every v2 handshake is authoritative for that cap
//!   (present re-applies, absent clears), so a worker that outlives its
//!   coordinator always adopts the current coordinator's bound.
//! * **Run durability** ([`supervisor::checkpoint`], [`supervisor::serve`])
//!   — the search-as-a-service tier.  `--checkpoint-dir <dir>` attaches a
//!   crash-safe run ledger: after every generation (barrier epoch, or
//!   steady-state quantum on the serial scheduler) the full search state —
//!   per-island archives, operator/supervisor residue, PRNG cursors,
//!   adaptive intervals, steady scheduler order and mailboxes — is
//!   committed as an atomically-renamed JSON snapshot keyed by the same
//!   `suite_tag ^ MachineSpec::fingerprint()` as the eval cache, with the
//!   cache snapshot alongside.  `avo evolve --resume <dir>` restores the
//!   saved search config and state and continues byte-identically to an
//!   uninterrupted run (pinned by `rust/tests/checkpoint_resume.rs`;
//!   `benches/checkpoint_resume.rs` gates commit latency).  On top,
//!   `avo serve` runs a minimal job queue over the remote tier's framing:
//!   `avo job` submits named runs (executed through the archipelago, one
//!   at a time), polls status, cancels cooperatively at generation
//!   boundaries, and fetches finished archives; per-job live metrics ride
//!   the telemetry hub.
//! * **Evaluation subsystem** ([`eval`]) — the batched [`eval::EvalBackend`]
//!   seam every scoring-function call goes through: [`eval::SimBackend`]
//!   (the simulator, with worker fan-out for batches),
//!   [`eval::RemoteBackend`] (the worker-fleet ground truth above),
//!   [`eval::CachedBackend`] (shared content-addressed memoization — with
//!   an optional oldest-first entry cap for week-long runs, batch-wide
//!   sharded probes, and a shared-reference cap setter the remote worker
//!   applies from the handshake — so duplicate genomes are never
//!   re-simulated), [`eval::DispatchPlane`] (cross-island batch
//!   coalescing above the whole stack), and [`eval::PersistentBackend`]
//!   (JSON cache persistence + `--warm-start`, carrying evaluations across
//!   runs; files are fingerprinted per workload and interchangeable
//!   between in-process and remote runs).  The determinism contract for
//!   cached, warm-started, and remote scores lives here.
//! * **Observability** ([`telemetry`]) — the window into a running
//!   search: a structured event bus ([`telemetry::TelemetrySink`]) that
//!   islands, eval layers, the remote fleet, and the supervisor publish
//!   typed events to; a crash-safe JSONL flight-recorder journal
//!   (`--journal`, byte-reproducible with `--trace-deterministic`); a
//!   live metrics endpoint (`--metrics-addr` + the `avo monitor`
//!   subcommand, over the remote tier's length-prefixed JSON framing);
//!   and fixed-bucket latency histograms (eval-batch wall clock, remote
//!   round-trip, per-stage) plus fleet idle-fraction saturation metrics,
//!   folded into `Metrics::to_json()` and `RunReport::summary()`.
//!   Telemetry is strictly observational: archives are byte-identical
//!   with it on or off (pinned by `rust/tests/telemetry.rs`).
//! * **Layer 2/1 (build-time Python)** — a parameterized Pallas
//!   flash-attention kernel realizing the genome's algorithmic space,
//!   AOT-lowered to HLO text artifacts the `runtime` module (behind the
//!   `pjrt` feature, which needs the vendored xla closure) executes via
//!   PJRT.
//! * **Hardware substrate** — the paper evolves CUDA kernels on B200 with a
//!   profiler; we reproduce that substrate with a cycle-approximate
//!   Blackwell-class simulator ([`sim`]) that prices exactly the
//!   micro-architectural dimensions the paper's §5 analysis manipulates
//!   (fences, pipeline overlap, register pressure) and *actually
//!   miscomputes* under the hazard combinations an incorrect kernel would
//!   race on ([`sim::functional`]).
//!
//! See `DESIGN.md` for the substitution table and the per-experiment index
//! mapping every figure/table of the paper to a module + bench target.

pub mod agent;
pub mod baselines;
pub mod benchkit;
pub mod coordinator;
pub mod eval;
pub mod evolution;
pub mod islands;
pub mod json;
pub mod kernelspec;
pub mod knowledge;
pub mod prng;
pub mod repro;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod score;
pub mod sim;
pub mod store;
pub mod supervisor;
pub mod telemetry;
pub mod workload;

pub use eval::EvalBackend;
pub use kernelspec::KernelSpec;
pub use score::{BenchConfig, Evaluator, Score};
pub use sim::machine::MachineSpec;
pub use workload::Workload;
