//! # AVO — Agentic Variation Operators for Autonomous Evolutionary Search
//!
//! Full-system reproduction of the AVO paper (CS.LG 2026) on the
//! Rust + JAX + Pallas three-layer stack:
//!
//! * **Layer 3 (this crate)** — the paper's contribution: an evolutionary
//!   search coordinator whose variation operator is an autonomous agent
//!   ([`agent::AvoAgent`]) that profiles the current best kernel, consults a
//!   knowledge base ([`knowledge`]) and the full lineage ([`evolution`]),
//!   proposes edits to a typed kernel genome ([`kernelspec::KernelSpec`]),
//!   evaluates them against the scoring function ([`score`]), diagnoses and
//!   repairs failures, and commits improvements — supervised against stalls
//!   and unproductive cycles ([`supervisor`]).
//! * **Scale-out** — an island model ([`islands`]): N concurrent lineages
//!   with per-island PRNG streams and elite migration (ring /
//!   broadcast-best / random pairs); the paper's sequential regime is the
//!   one-island special case.
//! * **Evaluation subsystem** ([`eval`]) — the batched [`eval::EvalBackend`]
//!   seam every scoring-function call goes through: [`eval::SimBackend`]
//!   (the simulator, with worker fan-out for batches),
//!   [`eval::CachedBackend`] (shared content-addressed memoization, so
//!   duplicate genomes are never re-simulated), and
//!   [`eval::PersistentBackend`] (JSON cache persistence + `--warm-start`,
//!   carrying evaluations across runs).  The determinism contract for
//!   cached and warm-started scores lives here.
//! * **Layer 2/1 (build-time Python)** — a parameterized Pallas
//!   flash-attention kernel realizing the genome's algorithmic space,
//!   AOT-lowered to HLO text artifacts the `runtime` module (behind the
//!   `pjrt` feature, which needs the vendored xla closure) executes via
//!   PJRT.
//! * **Hardware substrate** — the paper evolves CUDA kernels on B200 with a
//!   profiler; we reproduce that substrate with a cycle-approximate
//!   Blackwell-class simulator ([`sim`]) that prices exactly the
//!   micro-architectural dimensions the paper's §5 analysis manipulates
//!   (fences, pipeline overlap, register pressure) and *actually
//!   miscomputes* under the hazard combinations an incorrect kernel would
//!   race on ([`sim::functional`]).
//!
//! See `DESIGN.md` for the substitution table and the per-experiment index
//! mapping every figure/table of the paper to a module + bench target.

pub mod agent;
pub mod baselines;
pub mod benchkit;
pub mod coordinator;
pub mod eval;
pub mod evolution;
pub mod islands;
pub mod json;
pub mod kernelspec;
pub mod knowledge;
pub mod prng;
pub mod repro;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod score;
pub mod sim;
pub mod store;
pub mod supervisor;

pub use eval::EvalBackend;
pub use kernelspec::KernelSpec;
pub use score::{BenchConfig, Evaluator, Score};
pub use sim::machine::MachineSpec;
