//! Figure/table regeneration harness: one function per table and figure of
//! the paper's evaluation section (see DESIGN.md §Per-experiment index).
//! The `repro` binary prints these; the criterion-style benches time their
//! underlying evaluation paths.

use crate::baselines::{self, ablations, AnchorCurve};
use crate::coordinator::{EvolutionDriver, RunConfig, RunReport};
use crate::kernelspec::KernelSpec;
use crate::prng::Rng;
use crate::score::{
    geomean, gqa_suite, mha_suite, BenchConfig, Evaluator, SEQ_LENS, TOTAL_TOKENS,
};

/// The paper's main run configuration (seed chosen once, recorded in
/// EXPERIMENTS.md; 40 commits like the 7-day run).
pub fn paper_run_config() -> RunConfig {
    RunConfig { seed: 42, target_commits: 40, max_steps: 400, ..RunConfig::default() }
}

/// Run (or re-run) the main MHA evolution — deterministic given the seed.
pub fn paper_run() -> RunReport {
    EvolutionDriver::new(paper_run_config()).run()
}

/// Simulated AVO curve for one masking regime, with the 10x-repeat
/// mean +/- std protocol of §4.1.
pub fn avo_curve(spec: &KernelSpec, causal: bool, repeats: usize) -> Vec<(u32, f64, f64)> {
    let ev = Evaluator::new(mha_suite());
    let sigma = ev.machine.noise_rel_sigma;
    let mut rng = Rng::new(0xF163_5EED);
    SEQ_LENS
        .iter()
        .map(|&n| {
            let cfg = BenchConfig::mha(TOTAL_TOKENS / n, n, causal);
            let base = ev.report(spec, &cfg).tflops;
            let samples: Vec<f64> = (0..repeats.max(1))
                .map(|_| base * (1.0 + sigma * rng.normal()))
                .collect();
            let mean = samples.iter().sum::<f64>() / samples.len() as f64;
            let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
                / samples.len() as f64;
            (n, mean, var.sqrt())
        })
        .collect()
}

fn row(label: &str, values: &[f64]) -> String {
    let mut s = format!("  {label:<26}");
    for v in values {
        s.push_str(&format!(" {v:8.1}"));
    }
    s.push('\n');
    s
}

fn anchor_row(label: &str, c: &AnchorCurve) -> String {
    row(label, &c.tflops)
}

/// Figure 3: MHA forward prefill TFLOPS, causal + non-causal.
/// `evolved` is the final kernel of an evolution run (pass
/// `baselines::evolved_genome()` to reproduce without re-running).
pub fn fig3(evolved: &KernelSpec) -> String {
    let mut out = String::from(
        "== Figure 3: MHA forward prefill (B200, hd=128, 16 heads, BF16; \
         batch x seq = 32k tokens) ==\n",
    );
    for causal in [false, true] {
        out.push_str(&format!(
            "-- {} --            seq:     4096     8192    16384    32768\n",
            if causal { "causal   " } else { "non-causal" }
        ));
        out.push_str(&anchor_row("cuDNN 9.19.1 (measured)", &baselines::cudnn_measured(causal)));
        out.push_str(&anchor_row("FA4 71bf77c  (measured)", &baselines::fa4_measured(causal)));
        let curve = avo_curve(evolved, causal, 10);
        let mut s = format!("  {:<26}", "AVO (ours, simulated)");
        for (_, mean, std) in &curve {
            s.push_str(&format!(" {mean:6.1}±{std:3.1}"));
        }
        out.push_str(&s);
        out.push('\n');
        // Gain lines like the paper's text.
        let cudnn = baselines::cudnn_measured(causal);
        let fa4 = baselines::fa4_measured(causal);
        let gains = |b: &AnchorCurve| -> (f64, f64) {
            let mut lo = f64::MAX;
            let mut hi = f64::MIN;
            for (i, (_, mean, _)) in curve.iter().enumerate() {
                let g = 100.0 * (mean / b.tflops[i] - 1.0);
                lo = lo.min(g);
                hi = hi.max(g);
            }
            (lo, hi)
        };
        let (clo, chi) = gains(&cudnn);
        let (flo, fhi) = gains(&fa4);
        out.push_str(&format!(
            "  vs cuDNN: {clo:+.1}%..{chi:+.1}%   vs FA4: {flo:+.1}%..{fhi:+.1}%\n",
        ));
    }
    out
}

/// Figure 4: GQA TFLOPS after the 30-minute transfer, both group sizes.
pub fn fig4(adapted: &KernelSpec) -> String {
    let mut out = String::from(
        "== Figure 4: GQA forward prefill (32 Q heads, hd=128, BF16) ==\n",
    );
    for kv in [4u32, 8] {
        for causal in [false, true] {
            let (cudnn, fa4) = baselines::gqa_anchors(kv, causal);
            out.push_str(&format!(
                "-- group {} (kv={kv}) {} -- seq:     4096     8192    16384    32768\n",
                32 / kv,
                if causal { "causal" } else { "non-causal" }
            ));
            out.push_str(&anchor_row("cuDNN (measured)", &cudnn));
            out.push_str(&anchor_row("FA4   (measured)", &fa4));
            let ev = Evaluator::new(gqa_suite(kv));
            let sim: Vec<f64> = SEQ_LENS
                .iter()
                .map(|&n| {
                    let cfg = BenchConfig::gqa(TOTAL_TOKENS / n, n, kv, causal);
                    ev.report(adapted, &cfg).tflops
                })
                .collect();
            out.push_str(&row("AVO (adapted, simulated)", &sim));
            let best_cudnn = sim
                .iter()
                .zip(cudnn.tflops)
                .map(|(s, a)| 100.0 * (s / a - 1.0))
                .fold(f64::MIN, f64::max);
            let best_fa4 = sim
                .iter()
                .zip(fa4.tflops)
                .map(|(s, a)| 100.0 * (s / a - 1.0))
                .fold(f64::MIN, f64::max);
            out.push_str(&format!(
                "  max gain vs cuDNN {best_cudnn:+.1}%, vs FA4 {best_fa4:+.1}%\n"
            ));
        }
    }
    out
}

/// Figures 5/6: the evolution trajectory of a run (running-best geomean,
/// per-config series, baseline hlines, new-best markers).
pub fn fig56(report: &RunReport, causal: bool) -> String {
    let tag = if causal { "5 (causal)" } else { "6 (non-causal)" };
    let mut out = format!(
        "== Figure {tag}: AVO evolution trajectory over {} committed versions ==\n",
        report.lineage.len()
    );
    let cudnn = baselines::cudnn_measured(causal).geomean();
    let fa4 = baselines::fa4_measured(causal).geomean();
    out.push_str(&format!(
        "baseline geomeans: cuDNN {cudnn:.0}, FA4 {fa4:.0} TFLOPS\n\
         ver   geomean  run-best  new?   4k      8k      16k     32k\n",
    ));
    for p in report.lineage.trajectory(causal) {
        let per: Vec<f64> = SEQ_LENS
            .iter()
            .map(|n| {
                p.per_config
                    .iter()
                    .find(|(name, _)| name.ends_with(&n.to_string()))
                    .map(|(_, t)| *t)
                    .unwrap_or(0.0)
            })
            .collect();
        out.push_str(&format!(
            "v{:<3} {:8.1} {:9.1}  {}  {:7.1} {:7.1} {:7.1} {:7.1}\n",
            p.version,
            p.geomean,
            p.running_best,
            if p.is_new_best { "*" } else { " " },
            per[0],
            per[1],
            per[2],
            per[3],
        ));
    }
    let final_best = report
        .lineage
        .trajectory(causal)
        .last()
        .map(|p| p.running_best)
        .unwrap_or(0.0);
    out.push_str(&format!(
        "final running-best {final_best:.1} TFLOPS ({}, {} vs cuDNN {cudnn:.0} / FA4 {fa4:.0})\n",
        if final_best > cudnn { "beats cuDNN" } else { "below cuDNN" },
        if final_best > fa4 { "beats FA4" } else { "below FA4" },
    ));
    out
}

/// Table 1: ablations of the three named optimizations.
pub fn table1() -> String {
    let ev = Evaluator::new(mha_suite());
    let mut out = String::from(
        "== Table 1: agent-discovered optimizations (geomean gain vs preceding \
         version) ==\n  optimization                          versions   non-causal  causal   \
         (paper nc / c)\n",
    );
    let cases = [
        ("Branchless accumulator rescaling", "v19->v20", ablations::branchless_rescale(), "+8.1% / +1.6%"),
        ("Correction/MMA pipeline overlap", "v29->v30", ablations::correction_overlap(), "+1.1% / +0.4%"),
        ("Register rebalancing (warp groups)", "v32->v33", ablations::register_rebalance(), "+2.1% / ~0%"),
    ];
    for (name, vers, (before, after), paper) in cases {
        let g = |spec: &KernelSpec, causal: bool| {
            geomean(SEQ_LENS.iter().map(|&n| {
                let cfg = BenchConfig::mha(TOTAL_TOKENS / n, n, causal);
                ev.report(spec, &cfg).tflops
            }))
        };
        let nc = 100.0 * (g(&after, false) / g(&before, false) - 1.0);
        let c = 100.0 * (g(&after, true) / g(&before, true) - 1.0);
        out.push_str(&format!(
            "  {name:<37} {vers:<9} {nc:+9.1}% {c:+8.1}%   ({paper})\n"
        ));
    }
    out
}

/// Figure 7 (Appendix A): AVO vs the FA4-paper-reported baseline numbers.
pub fn fig7(evolved: &KernelSpec) -> String {
    let mut out = String::from(
        "== Figure 7 (App. A): AVO vs FA4-paper-reported cuDNN/FA4 ==\n",
    );
    for causal in [false, true] {
        let (cudnn, fa4) = baselines::cudnn_fa4_reported(causal);
        out.push_str(&format!(
            "-- {} --            seq:     4096     8192    16384    32768\n",
            if causal { "causal   " } else { "non-causal" }
        ));
        out.push_str(&anchor_row("cuDNN (FA4-paper reported)", &cudnn));
        out.push_str(&anchor_row("FA4   (FA4-paper reported)", &fa4));
        let curve = avo_curve(evolved, causal, 10);
        let sim: Vec<f64> = curve.iter().map(|(_, m, _)| *m).collect();
        out.push_str(&row("AVO (ours, simulated)", &sim));
        let lohi = |b: &AnchorCurve| {
            let gains: Vec<f64> = sim
                .iter()
                .zip(b.tflops)
                .map(|(s, a)| 100.0 * (s / a - 1.0))
                .collect();
            (
                gains.iter().copied().fold(f64::MAX, f64::min),
                gains.iter().copied().fold(f64::MIN, f64::max),
            )
        };
        let (clo, chi) = lohi(&cudnn);
        let (flo, fhi) = lohi(&fa4);
        out.push_str(&format!(
            "  vs reported cuDNN: {clo:+.1}%..{chi:+.1}%   vs reported FA4: {flo:+.1}%..{fhi:+.1}%\n"
        ));
    }
    out
}

/// §4.4 scale statistics of a run.
pub fn stats(report: &RunReport) -> String {
    format!(
        "== §4.4 scale of exploration ==\n\
         committed versions          {}\n\
         variation steps             {}\n\
         internal evaluations        {}\n\
         optimization directions     {}\n\
         diagnose/repair cycles      {}\n\
         supervisor interventions    {}\n\
         best geomean                {:.1} TFLOPS\n",
        report.lineage.len(),
        report.steps,
        report.metrics.counter("evaluations"),
        report.metrics.counter("directions_explored"),
        report.metrics.counter("repairs"),
        report.interventions.len(),
        report.lineage.best_geomean(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_contains_paper_structure() {
        let text = fig3(&baselines::evolved_genome());
        assert!(text.contains("cuDNN"));
        assert!(text.contains("FA4"));
        assert!(text.contains("non-causal"));
        assert!(text.contains("vs cuDNN"));
        // 4 seq columns present.
        assert!(text.contains("32768"));
    }

    #[test]
    fn table1_reproduces_signs_and_magnitudes() {
        let t = table1();
        assert!(t.contains("Branchless"));
        // The nc branchless gain must print as a positive high-single-digit.
        let line = t.lines().find(|l| l.contains("Branchless")).unwrap();
        assert!(line.contains("+8.") || line.contains("+7."), "{line}");
    }

    #[test]
    fn fig7_reports_reported_baselines() {
        let t = fig7(&baselines::evolved_genome());
        assert!(t.contains("FA4-paper reported"));
        assert!(t.contains("vs reported cuDNN"));
    }

    #[test]
    fn fig4_has_both_groups() {
        let t = fig4(&baselines::evolved_genome());
        assert!(t.contains("group 8"));
        assert!(t.contains("group 4"));
        assert!(t.contains("max gain"));
    }

    #[test]
    fn avo_curve_noise_protocol() {
        let c = avo_curve(&baselines::evolved_genome(), true, 10);
        assert_eq!(c.len(), 4);
        for (_, mean, std) in c {
            assert!(mean > 0.0);
            assert!(std > 0.0 && std < mean * 0.02);
        }
    }
}
