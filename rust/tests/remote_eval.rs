//! Cross-backend equivalence and fault-injection suite for the remote
//! evaluation tier (real worker *processes*, spawned from the cargo-built
//! `avo` binary).
//!
//! The contract under test: a remote-backed evolve is indistinguishable
//! from the in-process `Persistent<Cached<Sim>>` stack — byte-identical
//! archives, identical cache hit/miss accounting, interchangeable
//! persisted caches — on every registered workload, and stays that way
//! when a worker is killed mid-batch (in-flight specs are requeued onto
//! the survivors).  The protocol-level unit tests (framing, in-thread
//! requeue, local fallback) live in `avo::eval::remote`; this file covers
//! the process topology end to end.

use std::path::PathBuf;

use avo::coordinator::{EvolutionDriver, RunConfig};
use avo::eval::RemoteBackend;
use avo::kernelspec::KernelSpec;
use avo::score::Evaluator;
use avo::EvalBackend;

/// The cargo-built coordinator binary, doubling as the worker program
/// (`avo eval-worker`).
fn worker_program() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_avo"))
}

fn tempdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("avo_remote_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn base_config(workload: &str, seed: u64) -> RunConfig {
    RunConfig {
        seed,
        target_commits: 3,
        max_steps: 15,
        workload: workload.to_string(),
        ..RunConfig::default()
    }
}

fn remote_config(workload: &str, seed: u64, workers: usize) -> RunConfig {
    let mut cfg = base_config(workload, seed);
    cfg.topology.remote.workers = workers;
    cfg.topology.remote.program = Some(worker_program());
    cfg
}

/// One workload's equivalence check: remote-backed evolve == in-process
/// evolve, byte for byte, with identical cache accounting.
fn assert_remote_matches_local(workload: &str) {
    let dir = tempdir(&format!("eq_{}", workload.replace(':', "_")));

    let mut local_cfg = base_config(workload, 11);
    local_cfg.lineage_path = Some(dir.join("local_lineage.json"));
    let local = EvolutionDriver::new(local_cfg).run();

    let mut remote_cfg = remote_config(workload, 11, 2);
    remote_cfg.lineage_path = Some(dir.join("remote_lineage.json"));
    let remote = EvolutionDriver::new(remote_cfg).run();

    let a = std::fs::read(dir.join("local_lineage.json")).unwrap();
    let b = std::fs::read(dir.join("remote_lineage.json")).unwrap();
    assert!(!a.is_empty());
    assert_eq!(a, b, "{workload}: remote archive diverges from in-process");

    // The cached layer above the backend saw the identical key sequence.
    for key in ["evaluations", "eval_cache_hits", "eval_cache_misses", "commits", "eval_batches"]
    {
        assert_eq!(
            local.metrics.counter(key),
            remote.metrics.counter(key),
            "{workload}: {key} diverges"
        );
    }
    assert_eq!(remote.metrics.counter("remote_workers"), 2, "{workload}");
    assert_eq!(remote.metrics.counter("remote_worker_deaths"), 0, "{workload}");
    assert_eq!(remote.metrics.counter("remote_fallback_specs"), 0, "{workload}");
    assert!(remote.summary().contains("remote eval workers"), "{}", remote.summary());
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn remote_matches_local_mha() {
    assert_remote_matches_local("mha");
}

#[test]
fn remote_matches_local_mqa() {
    assert_remote_matches_local("gqa:1");
}

#[test]
fn remote_matches_local_gqa4() {
    assert_remote_matches_local("gqa:4");
}

#[test]
fn remote_matches_local_decode32() {
    assert_remote_matches_local("decode:32");
}

#[test]
fn warm_start_roundtrips_across_backends() {
    let dir = tempdir("warm");

    // Cold remote run persists its evaluation cache.
    let mut cold_cfg = remote_config("decode:32", 5, 2);
    cold_cfg.lineage_path = Some(dir.join("cold_lineage.json"));
    cold_cfg.eval_cache_path = Some(dir.join(avo::eval::CACHE_FILE));
    EvolutionDriver::new(cold_cfg).run();
    let cold = std::fs::read(dir.join("cold_lineage.json")).unwrap();

    // Remote warm start: every evaluation served from the cold run's
    // cache, archive byte-identical.
    let mut warm_cfg = remote_config("decode:32", 5, 2);
    warm_cfg.lineage_path = Some(dir.join("warm_lineage.json"));
    warm_cfg.warm_start = Some(dir.clone());
    let warm = EvolutionDriver::new(warm_cfg).run();
    assert_eq!(cold, std::fs::read(dir.join("warm_lineage.json")).unwrap());
    assert!(warm.metrics.counter("eval_cache_warm_entries") > 0);
    assert_eq!(
        warm.metrics.counter("eval_cache_misses"),
        0,
        "warm remote run recomputed a cached evaluation"
    );

    // In-process warm start from the REMOTE-produced cache file: the
    // fingerprint and every entry are backend-agnostic.
    let mut local_cfg = base_config("decode:32", 5);
    local_cfg.lineage_path = Some(dir.join("local_warm_lineage.json"));
    local_cfg.warm_start = Some(dir.clone());
    let local = EvolutionDriver::new(local_cfg).run();
    assert_eq!(cold, std::fs::read(dir.join("local_warm_lineage.json")).unwrap());
    assert_eq!(local.metrics.counter("eval_cache_misses"), 0);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn worker_killed_mid_batch_requeues_and_archive_is_identical() {
    let dir = tempdir("fault");
    // Lookahead widens eval batches so the death strands several
    // in-flight specs at once, not just a singleton.
    let mut nofault_cfg = remote_config("mha", 7, 2);
    nofault_cfg.agent.lookahead = 4;
    nofault_cfg.lineage_path = Some(dir.join("nofault_lineage.json"));
    let nofault = EvolutionDriver::new(nofault_cfg).run();
    assert_eq!(nofault.metrics.counter("remote_worker_deaths"), 0);

    // Identical config, but worker 0 dies after serving 3 eval frames —
    // its next request is dropped mid-flight.
    let mut fault_cfg = remote_config("mha", 7, 2);
    fault_cfg.agent.lookahead = 4;
    fault_cfg.topology.remote.fail_after = Some(3);
    fault_cfg.lineage_path = Some(dir.join("fault_lineage.json"));
    let fault = EvolutionDriver::new(fault_cfg).run();

    assert_eq!(fault.metrics.counter("remote_worker_deaths"), 1);
    assert!(
        fault.metrics.counter("remote_requeued_specs") > 0,
        "death produced no requeue"
    );
    assert!(
        fault.summary().contains("died"),
        "summary hides the fault: {}",
        fault.summary()
    );
    // No score divergence: the requeued evaluations produced the exact
    // archive and cache accounting of the healthy run.
    let a = std::fs::read(dir.join("nofault_lineage.json")).unwrap();
    let b = std::fs::read(dir.join("fault_lineage.json")).unwrap();
    assert_eq!(a, b, "mid-batch worker kill changed the archive");
    for key in ["evaluations", "eval_cache_hits", "eval_cache_misses", "commits"] {
        assert_eq!(
            nofault.metrics.counter(key),
            fault.metrics.counter(key),
            "{key} diverges under fault"
        );
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn handshake_rejects_worker_with_mismatched_fingerprint() {
    // Coordinator scores mha; the spawned worker process hosts gqa:4.
    // The worker advertises/checks `suite_tag ^ MachineSpec::fingerprint()`
    // and must reject the attach instead of serving incomparable scores.
    let eval = Evaluator::for_workload(&*avo::workload::parse("mha").unwrap());
    let err = RemoteBackend::spawn_local(eval, "gqa:4", 1, Some(&worker_program()), None)
        .err()
        .expect("mismatched worker must be rejected at handshake");
    assert!(err.contains("fingerprint mismatch"), "{err}");
}

#[test]
fn standalone_eval_worker_binary_serves_identical_scores() {
    // The thin `eval_worker` bin speaks the same protocol as the
    // `avo eval-worker` subcommand.
    let eval = Evaluator::for_workload(&*avo::workload::parse("mha").unwrap());
    let program = PathBuf::from(env!("CARGO_BIN_EXE_eval_worker"));
    let backend =
        RemoteBackend::spawn_local(eval.clone(), "mha", 1, Some(&program), None).unwrap();
    for spec in [KernelSpec::naive(), avo::baselines::evolved_genome()] {
        let remote = backend.evaluate(&spec);
        let local = eval.evaluate(&spec);
        assert_eq!(remote.per_config, local.per_config);
        assert_eq!(remote.failure, local.failure);
    }
}
