//! Cross-backend equivalence and fault-injection suite for the remote
//! evaluation tier (real worker *processes*, spawned from the cargo-built
//! `avo` binary).
//!
//! The contract under test: a remote-backed evolve is indistinguishable
//! from the in-process `Persistent<Cached<Sim>>` stack — byte-identical
//! archives, identical cache hit/miss accounting, interchangeable
//! persisted caches — on every registered workload, and stays that way
//! when a worker is killed mid-batch (in-flight specs are requeued onto
//! the survivors).  The protocol-level unit tests (framing, in-thread
//! requeue, local fallback) live in `avo::eval::remote`; this file covers
//! the process topology end to end.

use std::net::TcpListener;
use std::path::PathBuf;
use std::sync::atomic::Ordering;

use avo::coordinator::{EvolutionDriver, RunConfig};
use avo::eval::remote::{serve, serve_frozen_v1, RemoteTopology, WorkerOptions};
use avo::eval::RemoteBackend;
use avo::kernelspec::KernelSpec;
use avo::score::Evaluator;
use avo::EvalBackend;

/// The cargo-built coordinator binary, doubling as the worker program
/// (`avo eval-worker`).
fn worker_program() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_avo"))
}

fn tempdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("avo_remote_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn base_config(workload: &str, seed: u64) -> RunConfig {
    RunConfig {
        seed,
        target_commits: 3,
        max_steps: 15,
        workload: workload.to_string(),
        ..RunConfig::default()
    }
}

fn remote_config(workload: &str, seed: u64, workers: usize) -> RunConfig {
    let mut cfg = base_config(workload, seed);
    cfg.topology.remote.workers = workers;
    cfg.topology.remote.program = Some(worker_program());
    cfg
}

/// One workload's equivalence check: remote-backed evolve == in-process
/// evolve, byte for byte, with identical cache accounting.
fn assert_remote_matches_local(workload: &str) {
    let dir = tempdir(&format!("eq_{}", workload.replace(':', "_")));

    let mut local_cfg = base_config(workload, 11);
    local_cfg.lineage_path = Some(dir.join("local_lineage.json"));
    let local = EvolutionDriver::new(local_cfg).run();

    let mut remote_cfg = remote_config(workload, 11, 2);
    remote_cfg.lineage_path = Some(dir.join("remote_lineage.json"));
    let remote = EvolutionDriver::new(remote_cfg).run();

    let a = std::fs::read(dir.join("local_lineage.json")).unwrap();
    let b = std::fs::read(dir.join("remote_lineage.json")).unwrap();
    assert!(!a.is_empty());
    assert_eq!(a, b, "{workload}: remote archive diverges from in-process");

    // The cached layer above the backend saw the identical key sequence.
    for key in ["evaluations", "eval_cache_hits", "eval_cache_misses", "commits", "eval_batches"]
    {
        assert_eq!(
            local.metrics.counter(key),
            remote.metrics.counter(key),
            "{workload}: {key} diverges"
        );
    }
    assert_eq!(remote.metrics.counter("remote_workers"), 2, "{workload}");
    assert_eq!(remote.metrics.counter("remote_worker_deaths"), 0, "{workload}");
    assert_eq!(remote.metrics.counter("remote_fallback_specs"), 0, "{workload}");
    assert!(remote.summary().contains("remote eval workers"), "{}", remote.summary());
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn remote_matches_local_mha() {
    assert_remote_matches_local("mha");
}

#[test]
fn remote_matches_local_mqa() {
    assert_remote_matches_local("gqa:1");
}

#[test]
fn remote_matches_local_gqa4() {
    assert_remote_matches_local("gqa:4");
}

#[test]
fn remote_matches_local_decode32() {
    assert_remote_matches_local("decode:32");
}

#[test]
fn warm_start_roundtrips_across_backends() {
    let dir = tempdir("warm");

    // Cold remote run persists its evaluation cache.
    let mut cold_cfg = remote_config("decode:32", 5, 2);
    cold_cfg.lineage_path = Some(dir.join("cold_lineage.json"));
    cold_cfg.eval_cache_path = Some(dir.join(avo::eval::CACHE_FILE));
    EvolutionDriver::new(cold_cfg).run();
    let cold = std::fs::read(dir.join("cold_lineage.json")).unwrap();

    // Remote warm start: every evaluation served from the cold run's
    // cache, archive byte-identical.
    let mut warm_cfg = remote_config("decode:32", 5, 2);
    warm_cfg.lineage_path = Some(dir.join("warm_lineage.json"));
    warm_cfg.warm_start = Some(dir.clone());
    let warm = EvolutionDriver::new(warm_cfg).run();
    assert_eq!(cold, std::fs::read(dir.join("warm_lineage.json")).unwrap());
    assert!(warm.metrics.counter("eval_cache_warm_entries") > 0);
    assert_eq!(
        warm.metrics.counter("eval_cache_misses"),
        0,
        "warm remote run recomputed a cached evaluation"
    );

    // In-process warm start from the REMOTE-produced cache file: the
    // fingerprint and every entry are backend-agnostic.
    let mut local_cfg = base_config("decode:32", 5);
    local_cfg.lineage_path = Some(dir.join("local_warm_lineage.json"));
    local_cfg.warm_start = Some(dir.clone());
    let local = EvolutionDriver::new(local_cfg).run();
    assert_eq!(cold, std::fs::read(dir.join("local_warm_lineage.json")).unwrap());
    assert_eq!(local.metrics.counter("eval_cache_misses"), 0);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn worker_killed_mid_batch_requeues_and_archive_is_identical() {
    let dir = tempdir("fault");
    // Lookahead widens eval batches so the death strands several
    // in-flight specs at once, not just a singleton.
    let mut nofault_cfg = remote_config("mha", 7, 2);
    nofault_cfg.agent.lookahead = 4;
    nofault_cfg.lineage_path = Some(dir.join("nofault_lineage.json"));
    let nofault = EvolutionDriver::new(nofault_cfg).run();
    assert_eq!(nofault.metrics.counter("remote_worker_deaths"), 0);

    // Identical config, but worker 0 dies after serving 3 eval frames —
    // its next request is dropped mid-flight.
    let mut fault_cfg = remote_config("mha", 7, 2);
    fault_cfg.agent.lookahead = 4;
    fault_cfg.topology.remote.fail_after = Some(3);
    fault_cfg.lineage_path = Some(dir.join("fault_lineage.json"));
    let fault = EvolutionDriver::new(fault_cfg).run();

    assert_eq!(fault.metrics.counter("remote_worker_deaths"), 1);
    assert!(
        fault.metrics.counter("remote_requeued_specs") > 0,
        "death produced no requeue"
    );
    assert!(
        fault.summary().contains("died"),
        "summary hides the fault: {}",
        fault.summary()
    );
    // No score divergence: the requeued evaluations produced the exact
    // archive and cache accounting of the healthy run.
    let a = std::fs::read(dir.join("nofault_lineage.json")).unwrap();
    let b = std::fs::read(dir.join("fault_lineage.json")).unwrap();
    assert_eq!(a, b, "mid-batch worker kill changed the archive");
    for key in ["evaluations", "eval_cache_hits", "eval_cache_misses", "commits"] {
        assert_eq!(
            nofault.metrics.counter(key),
            fault.metrics.counter(key),
            "{key} diverges under fault"
        );
    }
    std::fs::remove_dir_all(dir).ok();
}

/// Tentpole contract, process-level: once a spec has been computed
/// anywhere in the fleet, gossiped deltas keep every sibling from
/// recomputing it.  Two real worker processes, the same batch twice:
/// round one costs exactly one simulation per distinct spec fleet-wide,
/// round two is served entirely from worker caches — zero sibling
/// recompute, visible through the `dedup_saved` counter that backs the
/// `remote_dedup_saved` run metric.
#[test]
fn fleet_gossip_prevents_sibling_recompute() {
    let eval = Evaluator::for_workload(&*avo::workload::parse("mha").unwrap());
    let backend =
        RemoteBackend::spawn_local(eval.clone(), "mha", 2, Some(&worker_program()), None)
            .unwrap();
    let specs = vec![
        KernelSpec::naive(),
        avo::baselines::fa4_genome(),
        avo::baselines::evolved_genome(),
        avo::baselines::cudnn_genome(),
    ];
    let first = backend.evaluate_batch(&specs);
    let second = backend.evaluate_batch(&specs);
    for ((a, b), spec) in first.iter().zip(&second).zip(&specs) {
        let local = eval.evaluate(spec);
        assert_eq!(a.per_config, local.per_config, "cache-served score diverges");
        assert_eq!(b.per_config, local.per_config, "cache-served score diverges");
    }
    let stats = backend.stats();
    // Round 1: each distinct spec simulated exactly once, on whichever
    // worker its chunk landed.  Round 2: every frame's piggybacked
    // deltas are merged before the worker probes its cache, so even
    // chunks that hop workers between rounds are pure hits.
    assert_eq!(
        stats.fleet_misses.load(Ordering::SeqCst),
        specs.len() as u64,
        "fleet recomputed a spec a sibling already produced"
    );
    assert_eq!(
        stats.dedup_saved.load(Ordering::SeqCst),
        specs.len() as u64,
        "warm round was not served entirely from worker caches"
    );
}

/// A worker that dies mid-run and then comes back on the SAME endpoint
/// is re-attached (handshake replay + ledger snapshot), the re-attach is
/// journaled, and the archive stays byte-identical to the in-process
/// run — fault recovery is pure capacity restoration.
#[test]
fn midrun_reattach_keeps_archive_byte_identical_and_is_journaled() {
    let dir = tempdir("reattach");

    let mut local_cfg = base_config("mha", 13);
    local_cfg.agent.lookahead = 4;
    local_cfg.lineage_path = Some(dir.join("local_lineage.json"));
    EvolutionDriver::new(local_cfg).run();

    // Flaky external worker: serves 2 eval frames, drops the connection,
    // then rebinds the same port (std listeners set SO_REUSEADDR on
    // Unix) and serves healthy — the shape of a restarted fleet node.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let rebind = addr.clone();
    let flaky = std::thread::spawn(move || {
        let workload = avo::workload::parse("mha").unwrap();
        let eval = Evaluator::for_workload(&*workload);
        let opts = WorkerOptions {
            once: true,
            fail_after: Some(2),
            eval_workers: 2,
            ..WorkerOptions::default()
        };
        serve(listener, &eval, &opts).unwrap();
        let listener = TcpListener::bind(&rebind).unwrap();
        let opts = WorkerOptions { once: true, eval_workers: 2, ..WorkerOptions::default() };
        serve(listener, &eval, &opts).unwrap();
    });
    // Healthy sibling keeps the run moving while the flaky node is down.
    let steady_listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let steady_addr = steady_listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        let workload = avo::workload::parse("mha").unwrap();
        let eval = Evaluator::for_workload(&*workload);
        let opts = WorkerOptions { once: true, eval_workers: 2, ..WorkerOptions::default() };
        serve(steady_listener, &eval, &opts).unwrap();
    });

    let mut cfg = base_config("mha", 13);
    cfg.agent.lookahead = 4;
    cfg.lineage_path = Some(dir.join("remote_lineage.json"));
    cfg.topology.remote.connect = vec![addr, steady_addr];
    cfg.topology.remote.reattach_cooldown_ms = 0;
    cfg.telemetry.journal = Some(dir.join("journal.jsonl"));
    cfg.telemetry.deterministic = true;
    let report = EvolutionDriver::new(cfg).run();

    assert_eq!(report.metrics.counter("remote_worker_deaths"), 1);
    assert_eq!(report.metrics.counter("remote_fallback_specs"), 0);
    assert!(
        report.metrics.counter("remote_reattaches") >= 1,
        "restarted worker was never re-attached"
    );
    assert!(
        report.summary().contains("re-attached"),
        "summary hides the re-attach: {}",
        report.summary()
    );
    let journal = std::fs::read_to_string(dir.join("journal.jsonl")).unwrap();
    assert!(
        journal.contains("\"event\":\"worker_reattached\""),
        "journal missing worker_reattached event"
    );

    let a = std::fs::read(dir.join("local_lineage.json")).unwrap();
    let b = std::fs::read(dir.join("remote_lineage.json")).unwrap();
    assert_eq!(a, b, "mid-run re-attach changed the archive");
    flaky.join().unwrap();
    std::fs::remove_dir_all(dir).ok();
}

/// A pre-fabric (protocol-1) worker in a mixed fleet: the coordinator
/// downgrades that connection — no gossip fields, plain `scores`
/// replies — and the archive still matches the in-process run byte for
/// byte.  Rolling fleet upgrades can't corrupt a search.
#[test]
fn v1_worker_in_mixed_fleet_keeps_archive_byte_identical() {
    let dir = tempdir("v1_fleet");

    let mut local_cfg = base_config("gqa:1", 17);
    local_cfg.lineage_path = Some(dir.join("local_lineage.json"));
    EvolutionDriver::new(local_cfg).run();

    let v1_listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let v1_addr = v1_listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        let workload = avo::workload::parse("gqa:1").unwrap();
        let eval = Evaluator::for_workload(&*workload);
        serve_frozen_v1(v1_listener, &eval, "gqa:1", true).unwrap();
    });
    let v2_listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let v2_addr = v2_listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        let workload = avo::workload::parse("gqa:1").unwrap();
        let eval = Evaluator::for_workload(&*workload);
        let opts = WorkerOptions {
            workload: "gqa:1".to_string(),
            once: true,
            eval_workers: 2,
            ..WorkerOptions::default()
        };
        serve(v2_listener, &eval, &opts).unwrap();
    });

    let mut cfg = base_config("gqa:1", 17);
    cfg.lineage_path = Some(dir.join("mixed_lineage.json"));
    cfg.topology.remote.connect = vec![v1_addr, v2_addr];
    let report = EvolutionDriver::new(cfg).run();
    assert_eq!(report.metrics.counter("remote_workers"), 2);
    assert_eq!(report.metrics.counter("remote_worker_deaths"), 0);
    assert_eq!(report.metrics.counter("remote_fallback_specs"), 0);

    let a = std::fs::read(dir.join("local_lineage.json")).unwrap();
    let b = std::fs::read(dir.join("mixed_lineage.json")).unwrap();
    assert_eq!(a, b, "v1 worker in the fleet changed the archive");
    std::fs::remove_dir_all(dir).ok();
}

/// Worker caches outlive coordinator runs: a second identical run
/// against the SAME warm external fleet is served largely from
/// worker-side caches (surfaced as `remote_dedup_saved`), and both runs'
/// archives match the in-process ground truth byte for byte.
#[test]
fn warm_external_fleet_dedups_a_second_run() {
    let dir = tempdir("warm_fleet");

    let mut local_cfg = base_config("mha", 19);
    local_cfg.lineage_path = Some(dir.join("local_lineage.json"));
    EvolutionDriver::new(local_cfg).run();

    // Long-lived fleet (once = false): each worker's Cached<Sim> stack
    // persists across both coordinator attachments.
    let mut addrs = Vec::new();
    for _ in 0..2 {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        addrs.push(listener.local_addr().unwrap().to_string());
        std::thread::spawn(move || {
            let workload = avo::workload::parse("mha").unwrap();
            let eval = Evaluator::for_workload(&*workload);
            let opts = WorkerOptions { eval_workers: 2, ..WorkerOptions::default() };
            serve(listener, &eval, &opts).unwrap();
        });
    }

    let run = |tag: &str| {
        let mut cfg = base_config("mha", 19);
        cfg.lineage_path = Some(dir.join(format!("{tag}_lineage.json")));
        cfg.topology.remote.connect = addrs.clone();
        EvolutionDriver::new(cfg).run()
    };
    let cold = run("cold");
    let warm = run("warm");
    assert_eq!(cold.metrics.counter("remote_worker_deaths"), 0);
    assert!(
        warm.metrics.counter("remote_dedup_saved") > 0,
        "warm fleet served nothing from cache"
    );
    assert!(
        warm.summary().contains("fleet dedup saved"),
        "summary hides the fleet dedup: {}",
        warm.summary()
    );

    let local = std::fs::read(dir.join("local_lineage.json")).unwrap();
    for tag in ["cold", "warm"] {
        let bytes = std::fs::read(dir.join(format!("{tag}_lineage.json"))).unwrap();
        assert_eq!(local, bytes, "{tag} fleet run diverges from in-process");
    }
    std::fs::remove_dir_all(dir).ok();
}

/// Every v2 handshake is authoritative for `cache_cap` — absent field
/// included.  A long-lived worker first serves a coordinator that caps
/// its cache at one entry; a second coordinator that ships NO cap then
/// attaches to the same worker and must see the bound cleared, not
/// inherit the previous coordinator's stale cap.
#[test]
fn reattached_worker_adopts_current_cache_cap() {
    // Long-lived external worker (once = false): its Cached<Sim> stack —
    // and any cap a handshake applied to it — outlives each coordinator.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        let workload = avo::workload::parse("mha").unwrap();
        let eval = Evaluator::for_workload(&*workload);
        let opts = WorkerOptions { eval_workers: 2, ..WorkerOptions::default() };
        serve(listener, &eval, &opts).unwrap();
    });

    let eval = Evaluator::for_workload(&*avo::workload::parse("mha").unwrap());
    let spec_a = KernelSpec::naive();
    let spec_b = avo::baselines::fa4_genome();
    // Gossip off: a re-sent spec must be served (or not) by the worker's
    // own cache, never re-warmed from the coordinator's ledger.
    let attach = |cache_cap: Option<usize>| {
        let topo = RemoteTopology {
            connect: vec![addr.clone()],
            gossip: false,
            cache_cap,
            ..RemoteTopology::default()
        };
        RemoteBackend::from_topology(eval.clone(), "mha", &topo).unwrap()
    };

    // Coordinator #1 caps the worker cache at one entry: B evicts A.
    let capped = attach(Some(1));
    for spec in [&spec_a, &spec_b] {
        assert_eq!(capped.evaluate(spec).per_config, eval.evaluate(spec).per_config);
    }
    assert_eq!(capped.stats().fleet_misses.load(Ordering::SeqCst), 2);
    drop(capped);

    // Coordinator #2 ships no cap.  Its handshake must CLEAR the stale
    // bound: the re-sent A misses once (B evicted it under cap 1), and
    // with the cache unbounded again both follow-ups are pure hits.  A
    // worker still pinned at one entry would miss all three.
    let uncapped = attach(None);
    for spec in [&spec_a, &spec_b, &spec_a] {
        assert_eq!(uncapped.evaluate(spec).per_config, eval.evaluate(spec).per_config);
    }
    let stats = uncapped.stats();
    assert_eq!(
        stats.fleet_misses.load(Ordering::SeqCst),
        1,
        "worker did not adopt the new coordinator's (absent) cache_cap"
    );
    assert_eq!(
        stats.dedup_saved.load(Ordering::SeqCst),
        2,
        "worker cache still bound by the previous coordinator's stale cap"
    );
}

#[test]
fn handshake_rejects_worker_with_mismatched_fingerprint() {
    // Coordinator scores mha; the spawned worker process hosts gqa:4.
    // The worker advertises/checks `suite_tag ^ MachineSpec::fingerprint()`
    // and must reject the attach instead of serving incomparable scores.
    let eval = Evaluator::for_workload(&*avo::workload::parse("mha").unwrap());
    let err = RemoteBackend::spawn_local(eval, "gqa:4", 1, Some(&worker_program()), None)
        .err()
        .expect("mismatched worker must be rejected at handshake");
    assert!(err.contains("fingerprint mismatch"), "{err}");
}

#[test]
fn standalone_eval_worker_binary_serves_identical_scores() {
    // The thin `eval_worker` bin speaks the same protocol as the
    // `avo eval-worker` subcommand.
    let eval = Evaluator::for_workload(&*avo::workload::parse("mha").unwrap());
    let program = PathBuf::from(env!("CARGO_BIN_EXE_eval_worker"));
    let backend =
        RemoteBackend::spawn_local(eval.clone(), "mha", 1, Some(&program), None).unwrap();
    for spec in [KernelSpec::naive(), avo::baselines::evolved_genome()] {
        let remote = backend.evaluate(&spec);
        let local = eval.evaluate(&spec);
        assert_eq!(remote.per_config, local.per_config);
        assert_eq!(remote.failure, local.failure);
    }
}
