//! Cache persistence contract: a `--warm-start` run reproduces the
//! cold run's archive byte-for-byte while serving evaluations from the
//! prior run's saved cache, and corrupt or mismatched cache files are
//! rejected instead of silently poisoning a run.

use avo::coordinator::{EvolutionDriver, RunConfig, RunReport};
use avo::eval::{CachedBackend, EvalBackend, PersistentBackend, SimBackend, CACHE_FILE};
use avo::score::{gqa_suite, Evaluator};

fn tempdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("avo_warm_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn small_config(seed: u64, islands: usize) -> RunConfig {
    let mut cfg = RunConfig {
        seed,
        target_commits: 5,
        max_steps: 25,
        ..RunConfig::default()
    };
    cfg.topology.islands = islands;
    cfg.topology.migrate_every = 2;
    cfg.topology.workers = 2;
    cfg
}

/// Full per-island commit-id sequences (ids are content hashes chained
/// through parents, so equality means byte-identical archives).
fn archives(report: &RunReport) -> Vec<Vec<u64>> {
    report
        .islands
        .iter()
        .map(|i| i.lineage.versions().iter().map(|c| c.id.0).collect())
        .collect()
}

#[test]
fn warm_start_roundtrip_reproduces_cold_archive_with_hits() {
    let dir = tempdir("roundtrip");

    // Run A: save the evaluation cache.
    let mut save_cfg = small_config(23, 1);
    save_cfg.eval_cache_path = Some(dir.join(CACHE_FILE));
    let run_a = EvolutionDriver::new(save_cfg).run();
    assert!(dir.join(CACHE_FILE).exists(), "cache file not written");

    // Run B: cold, same seed — the reference archive.
    let cold = EvolutionDriver::new(small_config(23, 1)).run();
    assert_eq!(archives(&run_a), archives(&cold));

    // Run C: warm-started from run A's cache.
    let mut warm_cfg = small_config(23, 1);
    warm_cfg.warm_start = Some(dir.clone());
    let warm = EvolutionDriver::new(warm_cfg).run();

    // Byte-identical archives...
    assert_eq!(archives(&warm), archives(&cold), "warm start changed the archive");
    assert_eq!(warm.steps, cold.steps);
    assert!((warm.lineage.best_geomean() - cold.lineage.best_geomean()).abs() < 1e-12);
    // ...with the warm cache doing the work: nonzero hits, strictly more
    // than the cold run's self-hits, and — since run A already paid for
    // every genome this trajectory evaluates — zero misses.
    let (warm_hits, cold_hits) = (
        warm.metrics.counter("eval_cache_hits"),
        cold.metrics.counter("eval_cache_hits"),
    );
    assert!(warm_hits > 0);
    assert!(warm_hits > cold_hits, "warm {warm_hits} vs cold {cold_hits}");
    assert_eq!(warm.metrics.counter("eval_cache_misses"), 0);
    assert!(warm.metrics.counter("eval_cache_warm_entries") > 0);
    assert!(warm.summary().contains("warm-start"), "{}", warm.summary());

    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn warm_start_reproduces_multi_island_archipelago() {
    let dir = tempdir("islands");

    let mut save_cfg = small_config(31, 3);
    save_cfg.eval_cache_path = Some(dir.join(CACHE_FILE));
    let cold = EvolutionDriver::new(save_cfg).run();

    let mut warm_cfg = small_config(31, 3);
    warm_cfg.warm_start = Some(dir.clone());
    let warm = EvolutionDriver::new(warm_cfg).run();

    assert_eq!(archives(&warm), archives(&cold));
    assert_eq!(warm.metrics.counter("eval_cache_misses"), 0);
    assert!(warm.metrics.counter("eval_cache_hits") > 0);

    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn saved_cache_includes_warm_entries_for_chained_runs() {
    // A -> B -> C: each run warm-starts from the previous and re-saves;
    // the chain must not lose entries (run C still runs miss-free).
    let dir_a = tempdir("chain_a");
    let dir_b = tempdir("chain_b");

    let mut cfg = small_config(7, 1);
    cfg.eval_cache_path = Some(dir_a.join(CACHE_FILE));
    EvolutionDriver::new(cfg).run();

    let mut cfg = small_config(7, 1);
    cfg.warm_start = Some(dir_a.clone());
    cfg.eval_cache_path = Some(dir_b.join(CACHE_FILE));
    let b = EvolutionDriver::new(cfg).run();
    assert_eq!(b.metrics.counter("eval_cache_misses"), 0);

    let mut cfg = small_config(7, 1);
    cfg.warm_start = Some(dir_b.clone());
    let c = EvolutionDriver::new(cfg).run();
    assert_eq!(c.metrics.counter("eval_cache_misses"), 0);

    std::fs::remove_dir_all(dir_a).ok();
    std::fs::remove_dir_all(dir_b).ok();
}

#[test]
fn corrupt_cache_file_is_rejected() {
    let dir = tempdir("corrupt");
    std::fs::write(dir.join(CACHE_FILE), "{\"version\": 1, garbage").unwrap();
    let cfg = small_config(3, 1);
    let backend = CachedBackend::new(SimBackend::new(cfg.evaluator(), 1));
    let err = PersistentBackend::warm_start(backend, &dir).unwrap_err();
    assert!(err.contains("json parse error"), "{err}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn cache_from_different_suite_is_rejected() {
    let dir = tempdir("suite");
    // Save under the default MHA suite...
    let mha = PersistentBackend::new(CachedBackend::new(SimBackend::new(
        small_config(3, 1).evaluator(),
        1,
    )));
    mha.evaluate(&avo::kernelspec::KernelSpec::naive());
    mha.save(&dir.join(CACHE_FILE)).unwrap();
    // ...and refuse to load under the GQA transfer suite.
    let gqa = CachedBackend::new(SimBackend::new(Evaluator::new(gqa_suite(4)), 1));
    let err = PersistentBackend::warm_start(gqa, &dir).unwrap_err();
    assert!(err.contains("fingerprint mismatch"), "{err}");
    std::fs::remove_dir_all(dir).ok();
}
