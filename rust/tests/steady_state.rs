//! Scheduling-mode contract: barrier mode stays the byte-pinned
//! reference regime (archives invariant across worker counts and
//! workloads, and across the work-stealing remote dispatch queue —
//! including a mid-run worker kill), while steady-state mode is
//! seed-deterministic in its serial regime (`--island-workers 1`) and
//! free-runs without deadlock under the tightest mailbox bound.

use std::path::PathBuf;

use avo::coordinator::{EvolutionDriver, RunConfig, RunReport, SchedulingMode};
use avo::islands::MigrationPolicy;

fn cfg_for(workload: &str, seed: u64, islands: usize, workers: usize) -> RunConfig {
    let mut cfg = RunConfig {
        seed,
        target_commits: 5,
        max_steps: 25,
        workload: workload.to_string(),
        ..RunConfig::default()
    };
    cfg.topology.islands = islands;
    cfg.topology.workers = workers;
    cfg.topology.migrate_every = 2;
    cfg
}

/// Full per-island commit-id sequences: ids are content hashes chained
/// through parents, so equality here means byte-identical archives.
fn archives(report: &RunReport) -> Vec<Vec<u64>> {
    report
        .islands
        .iter()
        .map(|i| i.lineage.versions().iter().map(|c| c.id.0).collect())
        .collect()
}

#[test]
fn default_scheduling_is_barrier() {
    assert_eq!(RunConfig::default().topology.scheduling, SchedulingMode::Barrier);
    // An explicit --barrier is the default spelled out: same archives.
    let implicit = EvolutionDriver::new(cfg_for("mha", 13, 3, 2)).run();
    let mut explicit_cfg = cfg_for("mha", 13, 3, 2);
    explicit_cfg.topology.scheduling = SchedulingMode::Barrier;
    let explicit = EvolutionDriver::new(explicit_cfg).run();
    assert_eq!(archives(&implicit), archives(&explicit));
}

#[test]
fn barrier_archives_invariant_across_worker_counts_all_workloads() {
    for workload in ["mha", "gqa:4", "decode:32"] {
        let mut baseline = None;
        for workers in [1usize, 2, 8] {
            let mut cfg = cfg_for(workload, 29, 3, workers);
            cfg.target_commits = 4;
            cfg.max_steps = 20;
            let ar = archives(&EvolutionDriver::new(cfg).run());
            match &baseline {
                None => baseline = Some(ar),
                Some(b) => assert_eq!(
                    b, &ar,
                    "{workload}: barrier archive diverged at {workers} workers"
                ),
            }
        }
    }
}

#[test]
fn single_island_archive_is_scheduling_mode_invariant() {
    // N = 1 has no migration and no interleaving: both schedulers reduce
    // to the same uninterrupted lineage, commit for commit.
    let barrier = EvolutionDriver::new(cfg_for("mha", 41, 1, 1)).run();
    let mut steady_cfg = cfg_for("mha", 41, 1, 1);
    steady_cfg.topology.scheduling = SchedulingMode::SteadyState;
    let steady = EvolutionDriver::new(steady_cfg).run();
    assert_eq!(archives(&barrier), archives(&steady));
    assert_eq!(barrier.steps, steady.steps);
    assert!(
        (barrier.lineage.best_geomean() - steady.lineage.best_geomean()).abs() < 1e-12
    );
}

#[test]
fn steady_state_serial_runs_are_deterministic() {
    let run = || {
        let mut cfg = cfg_for("mha", 57, 3, 1);
        cfg.topology.scheduling = SchedulingMode::SteadyState;
        EvolutionDriver::new(cfg).run()
    };
    let a = run();
    let b = run();
    assert_eq!(archives(&a), archives(&b), "serial steady-state diverged across runs");
    assert_eq!(a.steps, b.steps);
    // The serial FIFO actually exercises mailbox migration: island 0's
    // first published elite reaches island 1's drain point.
    let received: u64 =
        a.islands.iter().map(|i| i.metrics.counter("migrants_received")).sum();
    assert!(received > 0, "no migrant ever traveled through a mailbox");
}

#[test]
fn serial_steady_archive_is_dispatch_plane_invariant() {
    // In the serial regime the plane is bypassed entirely (one island
    // worker has nothing to coalesce), so `--dispatch-plane` must leave
    // the archive, step count, and dispatch metrics untouched.
    let run = |plane: bool| {
        let mut cfg = cfg_for("mha", 57, 3, 1);
        cfg.topology.scheduling = SchedulingMode::SteadyState;
        cfg.topology.dispatch_plane = plane;
        EvolutionDriver::new(cfg).run()
    };
    let off = run(false);
    let on = run(true);
    assert_eq!(
        archives(&off),
        archives(&on),
        "--dispatch-plane perturbed the serial steady-state archive"
    );
    assert_eq!(off.steps, on.steps);
    assert_eq!(on.metrics.counter("dispatch_batches"), 0, "plane engaged serially");
}

#[test]
fn threaded_steady_plane_coalesces_and_matches_serial_best() {
    // Multi-worker steady state with the plane on: the dispatcher must
    // actually coalesce (nonzero batches/tickets, width accounting
    // consistent), and — scores being pure — the run still drives every
    // island to a budget, with correct evaluations throughout.
    let mut cfg = cfg_for("mha", 63, 4, 4);
    cfg.topology.scheduling = SchedulingMode::SteadyState;
    cfg.topology.dispatch_plane = true;
    cfg.agent.lookahead = 4;
    let report = EvolutionDriver::new(cfg.clone()).run();
    assert_eq!(report.islands.len(), 4);
    for isl in &report.islands {
        assert!(
            isl.lineage.len() >= cfg.target_commits + 1 || isl.steps >= cfg.max_steps,
            "island {} stalled short of both budgets",
            isl.id
        );
    }
    let batches = report.metrics.counter("dispatch_batches");
    let tickets = report.metrics.counter("dispatch_tickets");
    let specs = report.metrics.counter("dispatch_coalesced_specs");
    assert!(batches > 0, "plane never dispatched: {}", report.summary());
    assert!(tickets >= batches, "every batch carries at least one ticket");
    assert!(specs >= tickets, "every ticket carries at least one spec");
    assert!(
        report.summary().contains("dispatch plane"),
        "{}",
        report.summary()
    );
}

#[test]
fn steady_adaptive_migration_is_deterministic_per_island() {
    // Adaptive intervals under steady state key off each island's own
    // quanta (there are no global epochs to count), and stay a pure
    // function of the seed in the serial regime.
    let run = || {
        let mut cfg = cfg_for("mha", 23, 3, 1);
        cfg.topology.scheduling = SchedulingMode::SteadyState;
        cfg.topology.adaptive_migration = true;
        cfg.topology.adaptive_stall_epochs = 1;
        EvolutionDriver::new(cfg).run()
    };
    let a = run();
    let b = run();
    assert_eq!(archives(&a), archives(&b));
    assert_eq!(
        a.metrics.counter("migration_interval_halvings"),
        b.metrics.counter("migration_interval_halvings"),
    );
}

#[test]
fn tight_mailboxes_never_deadlock_steady_runs() {
    // Capacity 1 maximizes overflow pressure (every second push to the
    // same inbox evicts); the run must still drive every island to
    // completion, serially and on a real worker pool.  Drop *semantics*
    // (oldest evicted, newcomer lands) are pinned by the mailbox unit
    // tests in `islands::migration`.
    for workers in [1usize, 4] {
        let mut cfg = cfg_for("mha", 71, 4, workers);
        cfg.topology.scheduling = SchedulingMode::SteadyState;
        cfg.topology.mailbox_capacity = 1;
        cfg.topology.migration = MigrationPolicy::BroadcastBest;
        let report = EvolutionDriver::new(cfg.clone()).run();
        assert_eq!(report.islands.len(), 4);
        for isl in &report.islands {
            assert!(
                isl.lineage.len() >= cfg.target_commits + 1 || isl.steps >= cfg.max_steps,
                "island {} stalled short of both budgets",
                isl.id
            );
        }
        // The dropped counter only appears in the summary when overflow
        // actually happened; either way the summary must render.
        assert!(!report.summary().is_empty());
    }
}

#[test]
fn worker_killed_mid_run_steals_chunks_and_archive_is_identical() {
    // Barrier mode over the work-stealing remote dispatch queue: a fleet
    // of 2 with lookahead-4 batches oversplits every round (nonzero
    // steals), and killing a worker mid-run must not perturb the archive
    // — stolen and requeued chunks land on the same scores.
    let program = PathBuf::from(env!("CARGO_BIN_EXE_avo"));
    let dir = std::env::temp_dir()
        .join(format!("avo_steady_kill_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let remote_cfg = |fail_after: Option<u64>, lineage: &str| {
        let mut cfg = cfg_for("mha", 7, 1, 1);
        cfg.target_commits = 3;
        cfg.max_steps = 15;
        cfg.agent.lookahead = 4;
        cfg.topology.remote.workers = 2;
        cfg.topology.remote.program = Some(program.clone());
        cfg.topology.remote.fail_after = fail_after;
        cfg.lineage_path = Some(dir.join(lineage));
        cfg
    };

    let nofault = EvolutionDriver::new(remote_cfg(None, "nofault.json")).run();
    assert_eq!(nofault.metrics.counter("remote_worker_deaths"), 0);
    assert!(
        nofault.metrics.counter("remote_chunks_stolen") > 0,
        "oversplit dispatch produced no steals: {}",
        nofault.summary()
    );
    assert!(nofault.summary().contains("chunks stolen"), "{}", nofault.summary());

    let fault = EvolutionDriver::new(remote_cfg(Some(3), "fault.json")).run();
    assert_eq!(fault.metrics.counter("remote_worker_deaths"), 1);

    let a = std::fs::read(dir.join("nofault.json")).unwrap();
    let b = std::fs::read(dir.join("fault.json")).unwrap();
    assert!(!a.is_empty());
    assert_eq!(a, b, "worker death perturbed the archive");
    std::fs::remove_dir_all(dir).ok();
}
