//! Workload-subsystem contract tests.
//!
//! * **Golden parity** — the MHA/GQA workloads are behavior-preserving: a
//!   driver run configured through the workload registry produces an
//!   archive byte-identical (commit-id sequence — content hashes chained
//!   through parents) to the pre-refactor construction, replicated here
//!   from first principles: `Evaluator::new` over a hand-built suite and
//!   `AvoAgent::new` with its built-in attention defaults.
//! * **Decode** — determinism, warm-start roundtrip, and the end-to-end
//!   acceptance bar: the best genome beats the naive decode seed on every
//!   suite cell.
//! * **Cache isolation** — same genome, different workload: distinct cache
//!   identity, and persisted caches refuse to cross workloads.

use avo::agent::{AvoAgent, VariationOperator};
use avo::coordinator::{EvolutionDriver, RunConfig};
use avo::eval::{CachedBackend, EvalBackend, PersistentBackend, SimBackend, CACHE_FILE};
use avo::evolution::Lineage;
use avo::kernelspec::KernelSpec;
use avo::score::{gqa_suite, mha_suite, BenchConfig, Evaluator};
use avo::supervisor::Supervisor;

/// The pre-refactor sequential construction, replicated verbatim: legacy
/// evaluator (no workload tag), the agent's built-in attention KB/phase
/// defaults, and the N = 1 archipelago loop (uncapped single epoch).
fn legacy_sequential_archive(
    suite: Vec<BenchConfig>,
    seed: u64,
    target_commits: usize,
    max_steps: usize,
) -> Vec<u64> {
    let cfg = RunConfig {
        seed,
        target_commits,
        max_steps,
        ..RunConfig::default()
    };
    let backend = CachedBackend::new(SimBackend::new(
        Evaluator::new(suite),
        cfg.eval_workers,
    ));
    let mut lineage = Lineage::new();
    let seed_spec = KernelSpec::naive();
    let seed_score = backend.evaluate(&seed_spec);
    assert!(seed_score.is_correct());
    lineage.seed(seed_spec, seed_score, "seed x0: naive tiled attention");
    let mut op = AvoAgent::new(cfg.agent.clone(), cfg.seed);
    let mut supervisor = Supervisor::new(cfg.supervisor.clone());
    let mut steps = 0usize;
    while lineage.len() < cfg.target_commits + 1 && steps < cfg.max_steps {
        steps += 1;
        let outcome = op.step(&mut lineage, &backend, steps);
        if let Some(directive) = supervisor.observe(&outcome, &lineage) {
            op.apply_directive(&directive);
        }
    }
    lineage.versions().iter().map(|c| c.id.0).collect()
}

fn workload_config(workload: &str, seed: u64, commits: usize, steps: usize) -> RunConfig {
    let mut cfg = RunConfig {
        seed,
        target_commits: commits,
        max_steps: steps,
        ..RunConfig::default()
    };
    cfg.workload = workload.to_string();
    cfg
}

fn driver_archive(workload: &str, seed: u64, commits: usize, steps: usize) -> Vec<u64> {
    let report = EvolutionDriver::new(workload_config(workload, seed, commits, steps)).run();
    report.lineage.versions().iter().map(|c| c.id.0).collect()
}

#[test]
fn mha_workload_reproduces_legacy_archive_byte_for_byte() {
    let golden = legacy_sequential_archive(mha_suite(), 5, 8, 40);
    assert!(golden.len() > 1, "legacy run must commit beyond the seed");
    assert_eq!(driver_archive("mha", 5, 8, 40), golden);
}

#[test]
fn gqa_workload_reproduces_legacy_archive_byte_for_byte() {
    let golden = legacy_sequential_archive(gqa_suite(4), 7, 6, 30);
    assert!(golden.len() > 1);
    assert_eq!(driver_archive("gqa:4", 7, 6, 30), golden);
}

#[test]
fn decode_run_beats_naive_seed_on_every_suite_cell() {
    // The acceptance bar: an end-to-end `--workload decode:32` run whose
    // best genome strictly beats the naive decode seed on every cell.
    let report =
        EvolutionDriver::new(workload_config("decode:32", 3, 10, 60)).run();
    assert!(report.lineage.len() > 1, "no commit landed on decode");
    let versions = report.lineage.versions();
    let seed_score = versions[0].score.clone();
    let best = report.lineage.best().expect("seeded lineage");
    for (name, seed_t) in &seed_score.per_config {
        assert!(name.starts_with("dec_b"), "{name}");
        let best_t = best.score.get(name).expect("same suite cells");
        assert!(
            best_t > *seed_t,
            "cell {name}: best {best_t} does not beat seed {seed_t}"
        );
    }
    assert!(report.summary().starts_with("[decode:32]"), "{}", report.summary());
}

#[test]
fn decode_runs_are_deterministic_per_seed() {
    let a = driver_archive("decode:32", 11, 6, 30);
    let b = driver_archive("decode:32", 11, 6, 30);
    assert_eq!(a, b);
    let c = driver_archive("decode:32", 12, 6, 30);
    assert_ne!(a, c, "distinct seeds must explore distinct trajectories");
}

#[test]
fn decode_warm_start_reproduces_cold_archive() {
    let dir = std::env::temp_dir().join(format!("avo_wk_warm_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut cold_cfg = workload_config("decode:32", 9, 5, 25);
    cold_cfg.eval_cache_path = Some(dir.join(CACHE_FILE));
    let cold = EvolutionDriver::new(cold_cfg).run();

    let mut warm_cfg = workload_config("decode:32", 9, 5, 25);
    warm_cfg.warm_start = Some(dir.clone());
    let warm = EvolutionDriver::new(warm_cfg).run();

    let ids = |r: &avo::coordinator::RunReport| -> Vec<u64> {
        r.lineage.versions().iter().map(|c| c.id.0).collect()
    };
    assert_eq!(ids(&cold), ids(&warm));
    assert!(warm.metrics.counter("eval_cache_hits") > 0);
    assert_eq!(warm.metrics.counter("eval_cache_misses"), 0);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn cross_workload_cache_identity_never_collides() {
    // The attention workloads keep the legacy cache identity (tag 0), so
    // eval_cache.json files saved before the workload subsystem still
    // warm-start their runs...
    let via_workload =
        Evaluator::for_workload(&avo::workload::GqaForward::new(4).unwrap());
    let manual = Evaluator::new(gqa_suite(4));
    assert_eq!(via_workload.suite, manual.suite);
    assert_eq!(
        EvalBackend::cache_tag(&via_workload),
        EvalBackend::cache_tag(&manual)
    );
    // ...while the decode workload's nonzero tag separates it even from an
    // ad-hoc evaluator over the very same cells.
    let decode = avo::workload::DecodeAttention::new(32).unwrap();
    let via_decode = Evaluator::for_workload(&decode);
    let manual_decode = Evaluator::new(via_decode.suite.clone());
    assert_ne!(
        EvalBackend::cache_tag(&via_decode),
        EvalBackend::cache_tag(&manual_decode)
    );
    // Registered workloads disagree pairwise.
    let specs = ["mha", "gqa:4", "gqa:8", "decode:8", "decode:32"];
    let tags: Vec<u64> = specs
        .iter()
        .map(|s| {
            EvalBackend::cache_tag(&Evaluator::for_workload(
                &*avo::workload::parse(s).unwrap(),
            ))
        })
        .collect();
    for i in 0..tags.len() {
        for j in i + 1..tags.len() {
            assert_ne!(tags[i], tags[j], "{} vs {}", specs[i], specs[j]);
        }
    }
}

/// `Evaluator::suite_tag` of `Evaluator::new(mha_suite())` and
/// `Evaluator::new(gqa_suite(4))` as computed by commit `bfe02eb` — the
/// last pre-workload-refactor revision, whose `suite_tag` had no
/// workload-tag fold at all.  These are the suite halves of the
/// fingerprints real `eval_cache.json` files written before the refactor
/// carry (the persisted fingerprint is `suite_tag ^
/// MachineSpec::fingerprint()`), so they are goldens, not derived values:
/// if either assertion below starts failing, the fix is to restore the
/// legacy hash identity, NOT to update the constant.  The machine half is
/// deliberately left live — recalibrating a cost constant is SUPPOSED to
/// invalidate saved caches.
const PRE_REFACTOR_MHA_SUITE_TAG: u64 = 0x274f235cfb6de46c;
const PRE_REFACTOR_GQA4_SUITE_TAG: u64 = 0xf583a045b691f414;

#[test]
fn legacy_cache_files_still_warm_start_attention_workloads() {
    // A cache saved under the pre-workload construction (ad-hoc evaluator,
    // no workload tag) must load under the MhaForward workload: the
    // attention workloads keep the legacy fingerprint.  Anchored against
    // hard-coded pre-refactor goldens so the check cannot go circular
    // (both sides built with post-refactor code would pass even if the
    // fingerprint drifted for everyone).
    assert_eq!(
        Evaluator::for_workload(&*avo::workload::parse("mha").unwrap()).suite_tag(),
        PRE_REFACTOR_MHA_SUITE_TAG
    );
    assert_eq!(
        Evaluator::for_workload(&*avo::workload::parse("gqa:4").unwrap()).suite_tag(),
        PRE_REFACTOR_GQA4_SUITE_TAG
    );
    let dir = std::env::temp_dir().join(format!("avo_wk_legacy_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    // A pre-refactor file: its fingerprint's suite half is the golden
    // constant (not recomputed by any current suite-hashing code) XOR the
    // live machine fingerprint.  It must pass the warm-start check.
    let legacy_fingerprint =
        PRE_REFACTOR_MHA_SUITE_TAG ^ avo::MachineSpec::b200().fingerprint();
    std::fs::write(
        dir.join(CACHE_FILE),
        format!(
            "{{\"version\": 1, \"fingerprint\": \"{legacy_fingerprint:016x}\", \
             \"entries\": []}}"
        ),
    )
    .unwrap();
    PersistentBackend::warm_start(
        CachedBackend::new(Evaluator::for_workload(
            &*avo::workload::parse("mha").unwrap(),
        )),
        &dir,
    )
    .expect("pre-refactor mha cache file must remain loadable");
    // And a populated legacy-construction cache round-trips its entries.
    let legacy = PersistentBackend::new(CachedBackend::new(Evaluator::new(mha_suite())));
    legacy.evaluate(&KernelSpec::naive());
    legacy.save(&dir.join(CACHE_FILE)).unwrap();
    let warm = PersistentBackend::warm_start(
        CachedBackend::new(Evaluator::for_workload(
            &*avo::workload::parse("mha").unwrap(),
        )),
        &dir,
    )
    .expect("legacy mha cache must remain loadable");
    assert_eq!(warm.warm_entries(), 1);
    warm.evaluate(&KernelSpec::naive());
    assert_eq!((warm.cache_stats().hits, warm.cache_stats().misses), (1, 0));
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn persisted_cache_refuses_to_cross_workloads() {
    let dir = std::env::temp_dir().join(format!("avo_wk_cross_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let decode = PersistentBackend::new(CachedBackend::new(Evaluator::for_workload(
        &*avo::workload::parse("decode:32").unwrap(),
    )));
    decode.evaluate(&KernelSpec::naive());
    decode.save(&dir.join(CACHE_FILE)).unwrap();
    let err = PersistentBackend::warm_start(
        CachedBackend::new(Evaluator::for_workload(
            &*avo::workload::parse("mha").unwrap(),
        )),
        &dir,
    )
    .unwrap_err();
    assert!(err.contains("fingerprint mismatch"), "{err}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn transfer_to_decode_adapts_an_evolved_forward_genome() {
    let driver = EvolutionDriver::new(RunConfig {
        seed: 2,
        ..RunConfig::default()
    });
    let report = driver
        .transfer_to("decode:32", avo::baselines::evolved_genome())
        .unwrap();
    // Scored on the decode suite, seeded from the evolved genome.
    let seed_commit = &report.lineage.versions()[0];
    for (name, t) in &seed_commit.score.per_config {
        assert!(name.starts_with("dec_b"), "{name}");
        assert!(*t > 0.0);
    }
    // The Update rule guarantees monotonicity from the transfer seed.
    assert!(report.lineage.best_geomean() >= seed_commit.score.geomean());
    // Unregistered targets error instead of running a bogus suite.
    assert!(driver.transfer_to("warp-drive:9", KernelSpec::naive()).is_err());
}

#[test]
fn transfer_back_to_mha_from_decode_best() {
    // The cross-workload path works in both directions: take a (short)
    // decode run's best genome and adapt it onto the MHA suite.
    let decode = EvolutionDriver::new(workload_config("decode:32", 4, 4, 20)).run();
    let best = decode.lineage.best().expect("seeded").spec.clone();
    let driver = EvolutionDriver::new(RunConfig { seed: 4, ..RunConfig::default() });
    let report = driver.transfer_to("mha", best).unwrap();
    let seed_commit = &report.lineage.versions()[0];
    assert!(seed_commit
        .score
        .per_config
        .iter()
        .all(|(n, _)| n.starts_with("mha_")));
    assert!(report.lineage.best_geomean() >= seed_commit.score.geomean());
}

#[test]
fn every_registered_workload_exposes_nondegenerate_anchors() {
    // ROADMAP follow-up closed by this suite: `gqa:1` (MQA) previously
    // parsed but had no calibrated anchors.  Every registered workload —
    // including the MQA extreme — must now expose anchors that (a) cover
    // every suite cell with a positive value, and (b) vary across cells
    // (a flat curve means a placeholder, not a calibration).
    for spec in ["mha", "gqa:1", "gqa:4", "gqa:8", "decode:8", "decode:32"] {
        let w = avo::workload::parse(spec).unwrap();
        let suite = w.suite();
        let anchors = w.anchors();
        assert!(!anchors.is_empty(), "{spec}: no anchors registered");
        for a in &anchors {
            for c in &suite {
                let t = a
                    .per_cell
                    .iter()
                    .find(|(n, _)| n == &c.name)
                    .map(|(_, t)| *t)
                    .unwrap_or(0.0);
                assert!(t > 0.0, "{spec}/{}: missing or zero anchor for {}", a.name, c.name);
            }
            let first = a.per_cell[0].1;
            assert!(
                a.per_cell.iter().any(|(_, t)| (*t - first).abs() > 1e-9),
                "{spec}/{}: flat (degenerate) anchor curve",
                a.name
            );
        }
        // Anchors are pairwise distinct baselines, not one curve twice.
        for i in 0..anchors.len() {
            for j in i + 1..anchors.len() {
                assert!(
                    anchors[i].per_cell != anchors[j].per_cell,
                    "{spec}: anchors {} and {} identical",
                    anchors[i].name,
                    anchors[j].name
                );
            }
        }
    }
}

#[test]
fn multi_island_decode_run_shares_cache_and_migrates() {
    let mut cfg = workload_config("decode:32", 13, 5, 30);
    cfg.topology.islands = 3;
    cfg.topology.migrate_every = 2;
    cfg.topology.workers = 2;
    let report = EvolutionDriver::new(cfg).run();
    assert_eq!(report.islands.len(), 3);
    assert!(report.metrics.counter("eval_cache_hits") > 0);
    for isl in &report.islands {
        let seed_g = isl.lineage.versions()[0].score.geomean();
        assert!(isl.lineage.best_geomean() >= seed_g);
    }
}
