//! End-to-end telemetry suite: the journal's reproducibility contract,
//! the live metrics endpoint over real TCP, and — the acceptance bar —
//! that telemetry is purely observational: a remote multi-island run with
//! a journal and a metrics server attached produces an archive
//! byte-identical to the same run with telemetry disabled.

use std::net::TcpStream;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use avo::coordinator::{EvolutionDriver, RunConfig};
use avo::eval::remote::{read_frame, write_frame};
use avo::json::Json;

fn worker_program() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_avo"))
}

fn tempdir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("avo_telemetry_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// 3 serial islands (`island_workers = 1`): journal event *order* is
/// deterministic, not just the per-event payloads.
fn journal_config(seed: u64, journal: PathBuf) -> RunConfig {
    let mut cfg = RunConfig {
        seed,
        target_commits: 3,
        max_steps: 30,
        ..RunConfig::default()
    };
    cfg.topology.islands = 3;
    cfg.topology.migrate_every = 2;
    cfg.topology.workers = 1;
    cfg.telemetry.journal = Some(journal);
    cfg.telemetry.deterministic = true;
    cfg
}

fn parsed_journal(path: &PathBuf) -> Vec<Json> {
    let body = std::fs::read_to_string(path).unwrap();
    body.lines()
        .map(|l| avo::json::parse(l).unwrap_or_else(|e| panic!("bad journal line {l}: {e}")))
        .collect()
}

fn tag(event: &Json) -> &str {
    event.get("event").and_then(|j| j.as_str()).unwrap_or("?")
}

#[test]
fn same_seed_journals_are_byte_identical() {
    let dir = tempdir("repro");
    let a_path = dir.join("a.jsonl");
    let b_path = dir.join("b.jsonl");
    EvolutionDriver::new(journal_config(23, a_path.clone())).run();
    EvolutionDriver::new(journal_config(23, b_path.clone())).run();
    let a = std::fs::read(&a_path).unwrap();
    let b = std::fs::read(&b_path).unwrap();
    assert!(!a.is_empty(), "journal is empty");
    assert_eq!(a, b, "same-seed deterministic journals diverge");

    // Schema sanity on the shared bytes: a well-formed flight recording
    // brackets the run and records commits against their islands.
    let events = parsed_journal(&a_path);
    assert_eq!(tag(&events[0]), "run_started");
    assert_eq!(tag(events.last().unwrap()), "run_finished");
    assert_eq!(
        events[0].get("islands").and_then(|j| j.as_u64()),
        Some(3),
        "{}",
        events[0].compact()
    );
    let commits: Vec<&Json> =
        events.iter().filter(|e| tag(e) == "step_committed").collect();
    assert!(!commits.is_empty(), "no step_committed events");
    for c in &commits {
        assert!(c.get("island").and_then(|j| j.as_u64()).is_some(), "{}", c.compact());
        // Commit ids are 16-hex strings (content hashes would lose
        // precision as JSON numbers).
        let id = c.get("commit").and_then(|j| j.as_str()).unwrap();
        assert_eq!(id.len(), 16, "{}", c.compact());
    }
    // Deterministic mode leaves no wall-clock anywhere.
    for e in &events {
        assert!(e.get("ts_ms").is_none(), "{}", e.compact());
        assert!(e.get("micros").is_none(), "{}", e.compact());
    }
    assert!(
        events.iter().any(|e| tag(e) == "cache_hit")
            && events.iter().any(|e| tag(e) == "cache_miss"),
        "cache traffic missing from journal"
    );
    std::fs::remove_dir_all(dir).ok();
}

/// Poll the metrics endpoint until a `done` snapshot arrives; returns
/// every snapshot observed (at least the final one).
fn poll_until_done(addr_cell: avo::telemetry::AddrCell) -> Vec<Json> {
    let deadline = Instant::now() + Duration::from_secs(120);
    // The server binds early in the run; wait for the announced address.
    let addr = loop {
        if let Some(a) = addr_cell.get() {
            break a;
        }
        assert!(Instant::now() < deadline, "metrics server never bound");
        std::thread::sleep(Duration::from_millis(10));
    };
    let mut stream = loop {
        match TcpStream::connect(&addr) {
            Ok(s) => break s,
            Err(_) => {
                assert!(Instant::now() < deadline, "could not connect to {addr}");
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    };
    stream.set_nodelay(true).ok();
    let mut snapshots = Vec::new();
    loop {
        assert!(Instant::now() < deadline, "no done snapshot before deadline");
        write_frame(&mut stream, &Json::obj([("type", Json::Str("snapshot".into()))]))
            .expect("send snapshot request");
        let snap = read_frame(&mut stream).expect("read snapshot");
        assert_eq!(snap.get("type").and_then(|j| j.as_str()), Some("snapshot"));
        let done = snap.get("done").and_then(|j| j.as_bool()) == Some(true);
        snapshots.push(snap);
        if done {
            return snapshots;
        }
        std::thread::sleep(Duration::from_millis(25));
    }
}

/// The acceptance run: 3 islands over 2 remote eval workers with a
/// journal AND a live metrics endpoint; snapshots stream per-island
/// bests, eval-batch latency, cache traffic, and fleet health — and the
/// archive is byte-identical to the same run with telemetry disabled.
#[test]
fn live_metrics_stream_and_archive_identity_under_full_telemetry() {
    let dir = tempdir("live");

    let base = |lineage: &str| {
        let mut cfg = RunConfig {
            seed: 11,
            target_commits: 3,
            max_steps: 30,
            ..RunConfig::default()
        };
        cfg.topology.islands = 3;
        cfg.topology.migrate_every = 2;
        cfg.topology.workers = 1;
        cfg.topology.remote.workers = 2;
        cfg.topology.remote.program = Some(worker_program());
        cfg.lineage_path = Some(dir.join(lineage));
        cfg
    };

    // Reference: telemetry fully disabled.
    EvolutionDriver::new(base("plain_lineage.json")).run();

    // Instrumented: journal + metrics endpoint on an ephemeral port.
    let mut cfg = base("telemetry_lineage.json");
    cfg.telemetry.journal = Some(dir.join("journal.jsonl"));
    cfg.telemetry.metrics_addr = Some("127.0.0.1:0".to_string());
    cfg.telemetry.deterministic = true;
    let addr_cell = cfg.telemetry.bound_addr.clone();
    let poller = std::thread::spawn(move || poll_until_done(addr_cell));
    let report = EvolutionDriver::new(cfg).run();
    let snapshots = poller.join().expect("poller panicked");

    // Telemetry is observational: byte-identical archive.
    let plain = std::fs::read(dir.join("plain_lineage.json")).unwrap();
    let instrumented = std::fs::read(dir.join("telemetry_lineage.json")).unwrap();
    assert!(!plain.is_empty());
    assert_eq!(plain, instrumented, "telemetry perturbed the archive");

    // The final snapshot carries the full saturation picture.
    let last = snapshots.last().unwrap();
    assert_eq!(last.get("done").and_then(|j| j.as_bool()), Some(true));
    assert_eq!(last.get("workload").and_then(|j| j.as_str()), Some("mha"));
    let islands = last.get("islands").and_then(|j| j.as_arr()).unwrap();
    assert_eq!(islands.len(), 3, "{}", last.compact());
    assert!(
        islands
            .iter()
            .any(|i| i.get("best").and_then(|j| j.as_f64()).unwrap_or(0.0) > 0.0),
        "no island reported a best score: {}",
        last.compact()
    );
    assert!(last.get("gen").and_then(|j| j.as_u64()).unwrap_or(0) > 0);
    let cache = last.get("cache").unwrap();
    assert!(
        cache.get("hits").and_then(|j| j.as_u64()).unwrap_or(0)
            + cache.get("misses").and_then(|j| j.as_u64()).unwrap_or(0)
            > 0
    );
    let batch = last.get("eval_batch").unwrap();
    assert!(
        batch.get("count").and_then(|j| j.as_u64()).unwrap_or(0) > 0,
        "eval-batch histogram is empty: {}",
        last.compact()
    );
    let fleet = last.get("fleet").unwrap();
    assert_eq!(fleet.get("workers").and_then(|j| j.as_u64()), Some(2));
    assert_eq!(fleet.get("deaths").and_then(|j| j.as_u64()), Some(0));
    let idle = fleet.get("idle_fraction").and_then(|j| j.as_f64()).unwrap();
    assert!((0.0..=1.0).contains(&idle), "idle fraction {idle} out of range");

    // The run report folded the same histograms + saturation counters.
    assert!(report.metrics.histogram("eval_batch").is_some());
    assert!(report.metrics.counter("remote_capacity_ms") > 0);
    assert!(
        report.summary().contains("eval batch p50"),
        "{}",
        report.summary()
    );

    // The monitor's renderer digests a real snapshot into one line.
    let line = avo::telemetry::monitor::render_status(last);
    assert!(line.contains("fleet 2/2"), "{line}");
    assert!(line.ends_with("| done"), "{line}");

    // And the journal recorded the whole run.
    let events = parsed_journal(&dir.join("journal.jsonl"));
    assert_eq!(tag(&events[0]), "run_started");
    assert_eq!(tag(events.last().unwrap()), "run_finished");
    assert!(events.iter().any(|e| tag(e) == "worker_attached"));
    assert!(events.iter().any(|e| tag(e) == "batch_dispatched"));
    std::fs::remove_dir_all(dir).ok();
}

/// Histograms surface through `Metrics::to_json()` and the text report
/// for plain (non-remote, non-telemetry) runs too: the per-stage
/// saturation profile is always on.
#[test]
fn run_metrics_carry_stage_histograms() {
    let cfg = RunConfig {
        seed: 3,
        target_commits: 2,
        max_steps: 10,
        ..RunConfig::default()
    };
    let report = EvolutionDriver::new(cfg).run();
    let j = report.metrics.to_json();
    let hists = j.get("histograms").unwrap().as_obj().unwrap();
    assert!(
        hists.keys().any(|k| k.starts_with("stage_")),
        "no per-stage histograms in {:?}",
        hists.keys().collect::<Vec<_>>()
    );
    assert!(hists.contains_key("eval_batch"), "eval_batch histogram missing");
    assert!(report.metrics.report().contains("p95="), "{}", report.metrics.report());
}
