//! Property-based invariants over the coordinator (seeded generators in
//! place of proptest, which is not vendored offline).  Each property runs
//! hundreds of randomized cases; failures print the offending seed/spec.

use avo::eval::{
    CachedBackend, CountingBackend, DispatchPlane, EvalBackend, PersistentBackend, RemoteBackend,
    SimBackend,
};
use avo::evolution::Lineage;
use avo::kernelspec::{all_edits, KernelSpec};
use avo::prng::Rng;
use avo::score::{geomean, mha_suite, Evaluator, Score};
use avo::sim::functional;
use avo::sim::machine::MachineSpec;
use avo::sim::pipeline::simulate;
use avo::score::BenchConfig;

/// Random genome via a random walk of catalogue edits from a random base.
fn random_spec(rng: &mut Rng) -> KernelSpec {
    let mut spec = match rng.below(3) {
        0 => KernelSpec::naive(),
        1 => avo::baselines::fa4_genome(),
        _ => avo::baselines::evolved_genome(),
    };
    let edits = all_edits();
    for _ in 0..rng.below(6) {
        spec = edits[rng.below(edits.len())].apply(&spec);
    }
    spec
}

#[test]
fn prop_validate_and_functional_are_total() {
    // No random genome may panic validation, functional execution, or the
    // cycle model; and a spec that validates must produce finite TFLOPS.
    let mut rng = Rng::new(0xABCD);
    let cfg = BenchConfig::mha(4, 8192, true);
    let m = MachineSpec::b200();
    for case in 0..400 {
        let spec = random_spec(&mut rng);
        let valid = spec.validate().is_ok();
        if valid {
            let _ = functional::check(&spec, true, 2, case);
            let r = simulate(&spec, &cfg, &m);
            assert!(r.tflops.is_finite() && r.tflops > 0.0, "case {case}: {spec:?}");
            assert!(r.tflops < m.peak_bf16_tflops, "case {case}: above peak");
        }
    }
}

#[test]
fn prop_score_gating_is_all_or_nothing() {
    // Either every config scores > 0 (correct) or every config is exactly 0.
    let mut rng = Rng::new(0xBEEF);
    let ev = Evaluator::new(mha_suite());
    for case in 0..150 {
        let spec = random_spec(&mut rng);
        let score = ev.evaluate(&spec);
        let zeros = score.per_config.iter().filter(|(_, t)| *t == 0.0).count();
        if score.is_correct() {
            assert_eq!(zeros, 0, "case {case}: gated cells on correct spec");
        } else {
            assert_eq!(zeros, score.per_config.len(), "case {case}: partial gating");
        }
    }
}

#[test]
fn prop_geomean_bounds() {
    // geomean lies within [min, max] of the per-config scores.
    let mut rng = Rng::new(0xC0DE);
    let ev = Evaluator::new(mha_suite());
    for _ in 0..60 {
        let spec = random_spec(&mut rng);
        let score = ev.evaluate(&spec);
        if !score.is_correct() {
            continue;
        }
        let vals: Vec<f64> = score.per_config.iter().map(|(_, t)| *t).collect();
        let g = geomean(vals.iter().copied());
        let lo = vals.iter().copied().fold(f64::MAX, f64::min);
        let hi = vals.iter().copied().fold(f64::MIN, f64::max);
        assert!(g >= lo - 1e-9 && g <= hi + 1e-9);
    }
}

#[test]
fn prop_lineage_running_best_monotone_and_head_connected() {
    // Whatever sequence of candidates is pushed through Update, the
    // running best never decreases, the head chain reaches the seed, and
    // the store verifies.
    let mut rng = Rng::new(0xD1CE);
    let ev = Evaluator::new(mha_suite());
    for _ in 0..12 {
        let mut lineage = Lineage::new();
        let seed = KernelSpec::naive();
        let s = ev.evaluate(&seed);
        lineage.seed(seed, s, "seed");
        let mut prev_best = lineage.best_geomean();
        for step in 1..=25 {
            let cand = random_spec(&mut rng);
            let score = ev.evaluate(&cand);
            let _ = lineage.update(cand, score, "prop", step);
            let best = lineage.best_geomean();
            assert!(best >= prev_best - 1e-9, "running best regressed");
            prev_best = best;
        }
        lineage.store.verify().unwrap();
        let head = lineage.head().unwrap();
        let chain = lineage.store.ancestry(head.id);
        assert_eq!(chain.len(), lineage.len(), "head chain disconnected");
        assert_eq!(chain.last().unwrap().step, 0);
    }
}

#[test]
fn prop_store_roundtrip_any_lineage() {
    let mut rng = Rng::new(0xFACE);
    let ev = Evaluator::new(mha_suite());
    let dir = std::env::temp_dir().join(format!("avo_prop_{}", std::process::id()));
    let path = dir.join("lineage.json");
    for case in 0..6 {
        let mut lineage = Lineage::new();
        let seed = KernelSpec::naive();
        let s = ev.evaluate(&seed);
        lineage.seed(seed, s, "seed");
        for step in 1..=10 {
            let cand = random_spec(&mut rng);
            let score = ev.evaluate(&cand);
            let _ = lineage.update(cand, score, &format!("case{case} step{step}"), step);
        }
        lineage.save(&path).unwrap();
        let loaded = Lineage::load(&path).unwrap();
        assert_eq!(loaded.len(), lineage.len());
        assert!((loaded.best_geomean() - lineage.best_geomean()).abs() < 1e-9);
        let a: Vec<_> = lineage.versions().iter().map(|c| c.id).collect();
        let b: Vec<_> = loaded.versions().iter().map(|c| c.id).collect();
        assert_eq!(a, b);
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn prop_repairs_terminate_and_often_fix() {
    // Chaining ranked repairs from any failing random genome terminates
    // within a small bound and usually reaches a passing spec.
    let mut rng = Rng::new(0x0FF1CE);
    let ev = Evaluator::new(mha_suite());
    let mut failing = 0;
    let mut fixed = 0;
    for _ in 0..200 {
        let spec = random_spec(&mut rng);
        let mut score = ev.evaluate(&spec);
        if score.is_correct() {
            continue;
        }
        failing += 1;
        let mut cand = spec;
        for _ in 0..4 {
            let Some(failure) = score.failure.clone() else { break };
            let repairs = avo::agent::diagnose::repairs_for(&failure, &cand);
            let Some(r) = repairs.first() else { break };
            cand = r.apply(&cand);
            score = ev.evaluate(&cand);
        }
        if score.is_correct() {
            fixed += 1;
        }
    }
    assert!(failing >= 20, "generator produced too few failures: {failing}");
    assert!(
        fixed as f64 >= failing as f64 * 0.8,
        "repairs fixed only {fixed}/{failing}"
    );
}

#[test]
fn prop_edits_compose_with_crossover() {
    // Crossover of two valid specs + validation never panics, and a
    // crossover of a spec with itself is the identity.
    let mut rng = Rng::new(0x70AD);
    for _ in 0..200 {
        let a = random_spec(&mut rng);
        let b = random_spec(&mut rng);
        let c = a.crossover(&b, &mut rng);
        let _ = c.validate();
        let same = a.crossover(&a.clone(), &mut rng);
        assert_eq!(same, a);
    }
}

#[test]
fn prop_decode_respects_one_cta_critical_path_floor() {
    // Across seeded-random genomes and decode cells, the decode makespan
    // can never beat a single CTA's own critical path: at most 16 split
    // CTAs share one tile's KV stream, so some CTA streams at least
    // ceil(blocks/16) K/V blocks, each costing no less than its raw HBM
    // transfer (pipeline-depth discount capped at 6%).  This pins the
    // floor added after the PR-3 review (fewer CTAs than SMs must not
    // "finish" faster than one work item can run).
    let mut rng = Rng::new(0xDEC0DE);
    let m = MachineSpec::b200();
    let batches = [1u32, 2, 4, 8, 32];
    let kv_lens = [2048u32, 4096, 16384, 32768];
    let kv_heads = [1u32, 2, 4, 8, 16, 32];
    let mut priced = 0usize;
    for case in 0..300 {
        let spec = random_spec(&mut rng);
        if spec.validate().is_err() {
            continue;
        }
        let cfg = BenchConfig::decode(
            batches[rng.below(batches.len())],
            kv_lens[rng.below(kv_lens.len())],
            32,
            kv_heads[rng.below(kv_heads.len())],
        );
        let r = simulate(&spec, &cfg, &m);
        assert!(
            r.tflops.is_finite() && r.tflops > 0.0,
            "case {case}: non-finite decode TFLOPS for {spec:?}"
        );
        assert!(r.tflops < m.peak_bf16_tflops, "case {case}: above peak");
        let n_blocks = (cfg.seq_len as u64).div_ceil(spec.block_k as u64).max(1);
        let kv_bytes = 2.0 * spec.block_k as f64 * cfg.head_dim as f64 * 2.0;
        let floor =
            n_blocks.div_ceil(16) as f64 * (kv_bytes / m.hbm_bytes_per_cycle()) * 0.94;
        assert!(
            r.total_cycles >= floor - 1e-6,
            "case {case}: makespan {} beats the one-CTA floor {floor} \
             ({n_blocks} blocks, {} on {:?})",
            r.total_cycles,
            cfg.name,
            spec
        );
        priced += 1;
    }
    assert!(priced >= 100, "generator priced too few valid decode cases: {priced}");
}

#[test]
fn prop_batched_equals_sequential_for_every_backend_layer() {
    // Whatever random batch is submitted — duplicates included — every
    // layer of the evaluation stack returns exactly the scores a
    // one-at-a-time pass over the bare Evaluator produces, in input
    // order.  The remote layer runs the real wire protocol against an
    // in-thread worker, so JSON f64 round-tripping is covered too.
    let mut rng = Rng::new(0x0B47C4);
    let eval = Evaluator::new(mha_suite());
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let server_eval = eval.clone();
    let server = std::thread::spawn(move || {
        let opts = avo::eval::remote::WorkerOptions {
            once: true,
            eval_workers: 2,
            ..avo::eval::remote::WorkerOptions::default()
        };
        avo::eval::remote::serve(listener, &server_eval, &opts)
    });
    let remote = RemoteBackend::connect(eval.clone(), &[addr]).unwrap();
    let layers: Vec<(&str, Box<dyn EvalBackend>)> = vec![
        ("evaluator", Box::new(eval.clone())),
        ("sim", Box::new(SimBackend::new(eval.clone(), 4))),
        ("cached", Box::new(CachedBackend::new(SimBackend::new(eval.clone(), 2)))),
        ("persistent", Box::new(PersistentBackend::new(CachedBackend::new(eval.clone())))),
        ("counting", Box::new(CountingBackend::new(eval.clone()))),
        ("remote", Box::new(remote)),
    ];
    for round in 0..6 {
        let mut specs: Vec<KernelSpec> = Vec::new();
        for _ in 0..rng.below(5) + 2 {
            specs.push(random_spec(&mut rng));
        }
        // In-batch duplicate: the dedup paths must serve the same bits.
        specs.push(specs[0].clone());
        let reference: Vec<Score> = specs.iter().map(|s| eval.evaluate(s)).collect();
        for (name, layer) in &layers {
            let batched = layer.evaluate_batch(&specs);
            assert_eq!(batched.len(), specs.len(), "round {round} layer {name}");
            for (i, (b, r)) in batched.iter().zip(&reference).enumerate() {
                assert_eq!(
                    b.per_config, r.per_config,
                    "round {round} layer {name} spec {i}: batched != sequential"
                );
                assert_eq!(b.failure, r.failure, "round {round} layer {name} spec {i}");
            }
            for (i, s) in specs.iter().enumerate() {
                let one = layer.evaluate(s);
                assert_eq!(
                    one.per_config, reference[i].per_config,
                    "round {round} layer {name} spec {i}: one-at-a-time diverges"
                );
            }
        }
    }
    drop(layers); // drops the RemoteBackend: shutdown frame ends the server
    server.join().unwrap().unwrap();
}

#[test]
fn prop_plane_interleavings_bit_equal_direct() {
    // However N concurrent "islands" interleave their submissions through
    // the dispatch plane — narrow tickets, wide tickets, windows smaller
    // and larger than any merged batch — each caller gets back exactly
    // the scores a direct call on the backend stack produces, in its own
    // submission order.  This is the byte-identity half of the plane's
    // contract (the coalescing half is gated by the bench).
    let eval = Evaluator::new(mha_suite());
    let backend = CachedBackend::new(SimBackend::new(eval.clone(), 2));
    for (round, &(islands, window)) in [(2usize, 1usize), (3, 4), (4, 64)].iter().enumerate() {
        let plane = DispatchPlane::new(&backend, window);
        std::thread::scope(|scope| {
            let plane = &plane;
            let dispatcher = scope.spawn(move || plane.run_dispatcher());
            let mut submitters = Vec::new();
            for island in 0..islands {
                let eval = eval.clone();
                submitters.push(scope.spawn(move || {
                    let mut rng =
                        Rng::new(0x15A_0D15 ^ ((round as u64) << 8) ^ island as u64);
                    for batch in 0..4 {
                        let mut specs: Vec<KernelSpec> = Vec::new();
                        for _ in 0..rng.below(4) + 1 {
                            specs.push(random_spec(&mut rng));
                        }
                        let scores = plane.evaluate_batch(&specs);
                        assert_eq!(
                            scores.len(),
                            specs.len(),
                            "round {round} island {island} batch {batch}"
                        );
                        for (i, (got, spec)) in scores.iter().zip(&specs).enumerate() {
                            let want = eval.evaluate(spec);
                            assert_eq!(
                                got.per_config, want.per_config,
                                "round {round} island {island} batch {batch} spec {i}: \
                                 plane != direct"
                            );
                            assert_eq!(
                                got.failure, want.failure,
                                "round {round} island {island} batch {batch} spec {i}"
                            );
                        }
                    }
                }));
            }
            for s in submitters {
                s.join().unwrap();
            }
            plane.shutdown();
            dispatcher.join().unwrap();
        });
    }
}

#[test]
fn prop_simulation_is_pure() {
    // Same (spec, config) must give bit-identical reports (no hidden
    // state in the cycle model) — required for replayable trajectories.
    let mut rng = Rng::new(0x5AFE);
    let m = MachineSpec::b200();
    for _ in 0..50 {
        let spec = random_spec(&mut rng);
        if spec.validate().is_err() {
            continue;
        }
        let cfg = BenchConfig::mha(2, 16384, rng.chance(0.5));
        let a = simulate(&spec, &cfg, &m);
        let b = simulate(&spec, &cfg, &m);
        assert_eq!(a.total_cycles.to_bits(), b.total_cycles.to_bits());
        assert_eq!(a.tflops.to_bits(), b.tflops.to_bits());
    }
}
