//! Integration across all three layers: execute the AOT-compiled Pallas
//! attention artifacts via PJRT from Rust and cross-check (a) the evolved
//! kernel against the exported jnp oracle artifact, and (b) the Rust
//! functional simulator's algorithm variants against the same data path.
//!
//! Requires `make artifacts`; tests skip (with a note) if absent so
//! `cargo test` stays runnable before the Python AOT step.

use avo::runtime::{default_artifact_dir, max_abs_diff, PjrtRuntime};

fn runtime_or_skip() -> Option<PjrtRuntime> {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(PjrtRuntime::new(&dir).expect("pjrt runtime"))
}

#[test]
fn evolved_kernel_matches_oracle_mha() {
    let Some(mut rt) = runtime_or_skip() else { return };
    // PJRT CPU client reports "cpu" (tfrt) or "host" depending on build.
    assert!(matches!(rt.platform().to_lowercase().as_str(), "cpu" | "host"));
    for tag in ["causal", "noncausal"] {
        let name = format!("mha_{tag}");
        let inputs = rt.random_inputs(&name, 7).unwrap();
        let evolved = rt.execute_f32(&name, &inputs).unwrap();
        let oracle = rt.execute_f32(&format!("ref_mha_{tag}"), &inputs).unwrap();
        assert_eq!(evolved.len(), 1);
        let err = max_abs_diff(&evolved[0], &oracle[0]);
        assert!(err < 2e-4, "{tag}: evolved vs oracle max err {err}");
    }
}

#[test]
fn fa4_design_kernel_matches_oracle() {
    let Some(mut rt) = runtime_or_skip() else { return };
    for tag in ["causal", "noncausal"] {
        let inputs = rt.random_inputs(&format!("mha_{tag}"), 11).unwrap();
        let fa4 = rt.execute_f32(&format!("mha_fa4_{tag}"), &inputs).unwrap();
        let oracle = rt.execute_f32(&format!("ref_mha_{tag}"), &inputs).unwrap();
        let err = max_abs_diff(&fa4[0], &oracle[0]);
        assert!(err < 2e-4, "{tag}: fa4-design vs oracle max err {err}");
    }
}

#[test]
fn evolved_and_fa4_variants_agree() {
    // Two distinct algorithmic realizations of attention must agree —
    // the Pallas-level analog of sim::functional's variant-pair property.
    let Some(mut rt) = runtime_or_skip() else { return };
    let inputs = rt.random_inputs("mha_causal", 13).unwrap();
    let a = rt.execute_f32("mha_causal", &inputs).unwrap();
    let b = rt.execute_f32("mha_fa4_causal", &inputs).unwrap();
    let err = max_abs_diff(&a[0], &b[0]);
    assert!(err < 2e-4, "variant disagreement {err}");
}

#[test]
fn gqa_kernels_match_oracle() {
    let Some(mut rt) = runtime_or_skip() else { return };
    for g in ["g8", "g4"] {
        for tag in ["causal", "noncausal"] {
            let name = format!("gqa_{g}_{tag}");
            let inputs = rt.random_inputs(&name, 17).unwrap();
            let out = rt.execute_f32(&name, &inputs).unwrap();
            let oracle = rt.execute_f32(&format!("ref_gqa_{g}_{tag}"), &inputs).unwrap();
            let err = max_abs_diff(&out[0], &oracle[0]);
            assert!(err < 2e-4, "{name}: max err {err}");
        }
    }
}

#[test]
fn transformer_block_runs_end_to_end() {
    // The L2 transformer block (attention + LN + MLP) through PJRT: shapes
    // hold, outputs finite, deterministic across executions.
    let Some(mut rt) = runtime_or_skip() else { return };
    let inputs = rt.random_inputs("block", 23).unwrap();
    let out1 = rt.execute_f32("block", &inputs).unwrap();
    let out2 = rt.execute_f32("block", &inputs).unwrap();
    assert_eq!(out1[0].len(), 512 * 512); // (1, 512, 512) flattened
    assert!(out1[0].iter().all(|x| x.is_finite()));
    assert_eq!(max_abs_diff(&out1[0], &out2[0]), 0.0);
}

#[test]
fn artifact_input_validation() {
    let Some(mut rt) = runtime_or_skip() else { return };
    // Wrong arity.
    let err = rt.execute_f32("mha_causal", &[vec![0.0; 4]]).unwrap_err();
    assert!(err.to_string().contains("expected 3 inputs"), "{err}");
    // Wrong size.
    let bad = vec![vec![0.0; 4], vec![0.0; 4], vec![0.0; 4]];
    let err = rt.execute_f32("mha_causal", &bad).unwrap_err();
    assert!(err.to_string().contains("size mismatch"), "{err}");
    // Unknown artifact.
    assert!(rt.execute_f32("nope", &[]).is_err());
}
