//! Operator-parity goldens for the staged agent runtime.
//!
//! The monolith→pipeline rewrite (`rust/src/agent/stages/`) claims to be
//! byte-for-byte behavior-preserving at default flags.  These tests pin
//! that claim the only non-circular way: each pre-refactor monolithic
//! operator (`AvoAgent::step`, `SingleTurnOperator::step`,
//! `FixedPipelineOperator::step`) is replicated here *from first
//! principles* — a literal port of the pre-refactor code against public
//! primitives — and its archive (the commit-id sequence, content hashes
//! chained through parents) must equal the staged pipeline's exactly.
//!
//! One deliberate deviation is pinned as such: the fixed-pipeline
//! operator's MAP-Elites cell index used to iterate a `HashMap`, whose
//! order varies per instance — the old operator was irreproducible
//! run-to-run.  The replica (and the rewrite) use a `BTreeMap`, so the
//! golden pins the new, deterministic behavior.
//!
//! The second half pins the refinement-lookahead contract: `--lookahead 1`
//! changes neither the archive nor the `evaluate_batch` call counts, while
//! `--lookahead k > 1` (with speculative repair) reduces backend calls per
//! evaluation without being allowed to break the run.

use std::collections::{BTreeMap, HashMap};

use avo::agent::{
    diagnose, AvoAgent, AvoConfig, FixedPipelineOperator, SingleTurnOperator,
    StepOutcome, VariationOperator,
};
use avo::eval::CountingBackend;
use avo::evolution::Lineage;
use avo::kernelspec::{all_edits, Direction, Edit, KernelSpec};
use avo::knowledge::KnowledgeBase;
use avo::prng::Rng;
use avo::score::{mha_suite, BenchConfig, Evaluator, Score};
use avo::sim::profile::{profile, ProfileReport};
use avo::supervisor::{Directive, Supervisor, SupervisorConfig};
use avo::workload::PhaseSchedule;

// ---------------------------------------------------------------------------
// The pre-refactor monolithic AVO agent, replicated verbatim.
// ---------------------------------------------------------------------------

#[derive(Default, Clone)]
struct Mem {
    tried: usize,
    barren: usize,
    banned_for: usize,
}

struct LegacyAvo {
    config: AvoConfig,
    kb: KnowledgeBase,
    phases: PhaseSchedule,
    rng: Rng,
    memory: HashMap<Direction, Mem>,
    boosted: Vec<Direction>,
}

impl LegacyAvo {
    fn new(config: AvoConfig, seed: u64) -> Self {
        assert!(!config.speculative_repair, "replica ports the sequential path");
        assert_eq!(config.lookahead, 1, "replica predates lookahead");
        LegacyAvo {
            config,
            kb: KnowledgeBase::paper_kb(),
            phases: PhaseSchedule::attention(),
            rng: Rng::new(seed),
            memory: HashMap::new(),
            boosted: Vec::new(),
        }
    }

    fn phase_directions(&self, committed: usize) -> &[Direction] {
        self.phases.for_phase(
            committed,
            self.config.structural_until,
            self.config.algorithmic_until,
        )
    }

    fn bottleneck_weights(&self, reports: &[ProfileReport]) -> HashMap<Direction, f64> {
        let mut w = HashMap::new();
        for r in reports {
            for b in &r.bottlenecks {
                *w.entry(b.direction).or_insert(0.0) += b.share;
            }
        }
        w
    }

    fn choose_direction(
        &mut self,
        weights: &HashMap<Direction, f64>,
        committed: usize,
    ) -> Direction {
        let phase = self.phase_directions(committed);
        let dirs: Vec<Direction> = Direction::ALL
            .into_iter()
            .filter(|d| {
                self.memory
                    .get(d)
                    .map(|m| m.banned_for == 0)
                    .unwrap_or(true)
            })
            .collect();
        let dirs = if dirs.is_empty() { Direction::ALL.to_vec() } else { dirs };
        let ws: Vec<f64> = dirs
            .iter()
            .map(|d| {
                let bottleneck = weights.get(d).copied().unwrap_or(0.01).max(0.01);
                let kb_prior = self
                    .kb
                    .retrieve(*d)
                    .first()
                    .map(|doc| doc.prior)
                    .unwrap_or(0.1);
                let barren = self.memory.get(d).map(|m| m.barren).unwrap_or(0);
                let novelty = self.config.novelty_decay.powi(barren as i32);
                let phase_mult = if phase.contains(d) { self.config.phase_boost } else { 1.0 };
                let boost = if self.boosted.contains(d) { 3.0 } else { 1.0 };
                bottleneck * kb_prior * novelty * phase_mult * boost
            })
            .collect();
        dirs[self.rng.weighted(&ws)]
    }

    fn propose_edit(&mut self, direction: Direction, base: &KernelSpec) -> Option<Edit> {
        let candidates: Vec<(Edit, f64)> = self
            .kb
            .edits_for(direction)
            .into_iter()
            .filter(|(e, _)| !e.is_noop(base))
            .collect();
        if candidates.is_empty() {
            return None;
        }
        let ws: Vec<f64> = candidates.iter().map(|(_, w)| *w).collect();
        Some(candidates[self.rng.weighted(&ws)].0.clone())
    }

    fn evaluate_with_repair(
        &mut self,
        eval: &Evaluator,
        mut cand: KernelSpec,
    ) -> (KernelSpec, Score, usize) {
        let mut score = eval.evaluate(&cand);
        let mut evals = 1;
        let mut repairs_left = self.config.repair_budget;
        while let Some(failure) = score.failure.clone() {
            if repairs_left == 0 {
                break;
            }
            repairs_left -= 1;
            let repairs = diagnose::repairs_for(&failure, &cand);
            if repairs.is_empty() {
                break;
            }
            cand = repairs[0].apply(&cand);
            score = eval.evaluate(&cand);
            evals += 1;
        }
        (cand, score, evals)
    }

    fn remember(&mut self, direction: Direction, produced_commit: bool) {
        let m = self.memory.entry(direction).or_default();
        m.tried += 1;
        if produced_commit {
            m.barren = 0;
        } else {
            m.barren += 1;
        }
    }

    fn decay_bans(&mut self) {
        for m in self.memory.values_mut() {
            m.banned_for = m.banned_for.saturating_sub(1);
        }
    }

    fn apply_directive(&mut self, directive: &Directive) {
        for d in &directive.ban {
            self.memory.entry(*d).or_default().banned_for = directive.ban_steps;
        }
        self.boosted = directive.boost.clone();
        if directive.reset_memory {
            for m in self.memory.values_mut() {
                m.barren = 0;
            }
        }
    }

    fn step(&mut self, lineage: &mut Lineage, eval: &Evaluator, step: usize) -> StepOutcome {
        let mut out = StepOutcome::default();
        self.decay_bans();
        let best = lineage.best().expect("lineage must be seeded").clone();

        // 1. Profile the flagship cells of each regime in the suite.
        let flagship: Vec<BenchConfig> = {
            let mut seen = Vec::new();
            let mut cells = Vec::new();
            for c in eval.suite.iter().rev() {
                if !seen.contains(&c.causal) {
                    seen.push(c.causal);
                    cells.push(c.clone());
                }
            }
            cells
        };
        let reports: Vec<ProfileReport> = flagship
            .iter()
            .map(|c| profile(&eval.report(&best.spec, c)))
            .collect();
        let weights = self.bottleneck_weights(&reports);

        // Occasional comparative read of an earlier lineage member.
        if lineage.len() > 2 && self.rng.chance(0.3) {
            let versions = lineage.versions();
            let pick = versions[self.rng.below(versions.len())];
            let _ = profile(&eval.report(&pick.spec, &flagship[0]));
        }

        // Inner loop: explore directions until the budget is spent or a
        // commit lands.
        let mut budget = self.config.inner_budget;
        let mut committed = None;
        while budget > 0 && committed.is_none() {
            let direction = self.choose_direction(&weights, lineage.len());
            if !out.directions.contains(&direction) {
                out.directions.push(direction);
            }

            // (The monolith's migrant branch drew no randomness with an
            // empty pool; the sequential replica has no migrants.)
            let candidate = if lineage.len() > 3 && self.rng.chance(self.config.crossover_prob)
            {
                let versions = lineage.versions();
                let donor = versions[self.rng.below(versions.len())];
                best.spec.crossover(&donor.spec, &mut self.rng)
            } else {
                match self.propose_edit(direction, &best.spec) {
                    Some(e) => e.apply(&best.spec),
                    None => {
                        budget -= 1;
                        self.remember(direction, false);
                        continue;
                    }
                }
            };

            // 4+5. Evaluate with diagnosis/repair.
            let (mut cand, mut score, evals) = self.evaluate_with_repair(eval, candidate);
            out.evaluations += evals;
            budget = budget.saturating_sub(evals);

            // 6. Refine: while improving, stack another edit.
            while budget > 0
                && score.is_correct()
                && score.geomean() > lineage.best_geomean()
                && self.rng.chance(0.5)
            {
                let Some(next) = self.propose_edit(direction, &cand) else { break };
                let stacked = next.apply(&cand);
                let (c2, s2, e2) = self.evaluate_with_repair(eval, stacked);
                out.evaluations += e2;
                budget = budget.saturating_sub(e2);
                if s2.is_correct() && s2.geomean() > score.geomean() {
                    cand = c2;
                    score = s2;
                } else {
                    break;
                }
            }

            // Commit strict improvements always; neutral refinements only
            // occasionally.
            let strict = score.geomean() > lineage.best_geomean() * (1.0 + 1e-12);
            let produced = score.is_correct()
                && (strict
                    || (score.geomean() >= lineage.best_geomean() && self.rng.chance(0.15)));
            if produced && cand != best.spec {
                if let Ok(id) = lineage.update(cand, score.clone(), "legacy", step) {
                    committed = Some(id);
                }
            }
            self.remember(direction, committed.is_some());
        }
        out.committed = committed;
        out
    }
}

// ---------------------------------------------------------------------------
// The pre-refactor monolithic baselines, replicated verbatim.
// ---------------------------------------------------------------------------

struct LegacySingleTurn {
    rng: Rng,
    temperature: f64,
}

impl LegacySingleTurn {
    fn new(seed: u64) -> Self {
        LegacySingleTurn { rng: Rng::new(seed), temperature: 0.02 }
    }

    fn step(&mut self, lineage: &mut Lineage, eval: &Evaluator, step: usize) -> StepOutcome {
        let mut out = StepOutcome::default();
        let parent = {
            let versions = lineage.versions();
            let best = lineage.best_geomean().max(1.0);
            let ws: Vec<f64> = versions
                .iter()
                .map(|c| ((c.score.geomean() - best) / (self.temperature * best)).exp())
                .collect();
            versions[self.rng.weighted(&ws)].spec.clone()
        };
        let edits: Vec<Edit> = all_edits()
            .into_iter()
            .filter(|e| !e.is_noop(&parent))
            .collect();
        let edit = edits[self.rng.below(edits.len())].clone();
        out.directions.push(edit.direction);
        let cand = edit.apply(&parent);
        let score = eval.evaluate(&cand);
        out.evaluations = 1;
        if score.is_correct() && score.geomean() >= lineage.best_geomean() {
            if let Ok(id) = lineage.update(cand, score, "legacy", step) {
                out.committed = Some(id);
            }
        }
        out
    }
}

struct LegacyFixedPipeline {
    rng: Rng,
    stats: HashMap<Direction, (usize, usize)>,
    kb: KnowledgeBase,
}

impl LegacyFixedPipeline {
    fn new(seed: u64) -> Self {
        LegacyFixedPipeline {
            rng: Rng::new(seed),
            stats: HashMap::new(),
            kb: KnowledgeBase::paper_kb(),
        }
    }

    fn step(&mut self, lineage: &mut Lineage, eval: &Evaluator, step: usize) -> StepOutcome {
        let mut out = StepOutcome::default();
        // MAP-Elites-lite parent selection.  Deliberate deviation from the
        // monolith, shared with the rewrite: a BTreeMap cell index (the
        // monolith's HashMap iterated in per-instance random order, so the
        // old operator could not be pinned at all).
        let parent = {
            let mut elites: BTreeMap<(u32, u32), &avo::store::Commit> = BTreeMap::new();
            for c in lineage.versions() {
                let key = (c.spec.block_q, c.spec.block_k);
                let cur = elites.entry(key).or_insert(c);
                if c.score.geomean() > cur.score.geomean() {
                    *cur = c;
                }
            }
            let elites: Vec<&avo::store::Commit> = elites.into_values().collect();
            let best = lineage.best_geomean().max(1.0);
            let ws: Vec<f64> = elites
                .iter()
                .map(|c| ((c.score.geomean() - best) / (0.03 * best)).exp())
                .collect();
            elites[self.rng.weighted(&ws)].spec.clone()
        };

        // PLAN: best summarized success rate.
        let direction = *Direction::ALL
            .iter()
            .max_by(|a, b| {
                let rate = |d| {
                    let (ok, tried) = self.stats.get(d).copied().unwrap_or((0, 0));
                    (ok as f64 + 1.0) / (tried as f64 + 2.0)
                };
                rate(a).partial_cmp(&rate(b)).unwrap()
            })
            .unwrap();
        out.directions.push(direction);

        // EXECUTE: one KB-weighted edit, single retry on failure.
        let candidates: Vec<(Edit, f64)> = self
            .kb
            .edits_for(direction)
            .into_iter()
            .filter(|(e, _)| !e.is_noop(&parent))
            .collect();
        if candidates.is_empty() {
            self.stats.entry(direction).or_insert((0, 0)).1 += 1;
            return out;
        }
        let ws: Vec<f64> = candidates.iter().map(|(_, w)| *w).collect();
        let edit = candidates[self.rng.weighted(&ws)].0.clone();
        let mut cand = edit.apply(&parent);
        let mut score = eval.evaluate(&cand);
        out.evaluations = 1;
        if let Some(failure) = score.failure.clone() {
            if let Some(repair) = diagnose::repairs_for(&failure, &cand).first() {
                cand = repair.apply(&cand);
                score = eval.evaluate(&cand);
                out.evaluations += 1;
            }
        }

        // SUMMARIZE + Update.
        let entry = self.stats.entry(direction).or_insert((0, 0));
        entry.1 += 1;
        if score.is_correct() && score.geomean() >= lineage.best_geomean() {
            if let Ok(id) = lineage.update(cand, score, "legacy", step) {
                self.stats.entry(direction).or_insert((0, 0)).0 += 1;
                out.committed = Some(id);
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Harnesses.
// ---------------------------------------------------------------------------

fn seeded_lineage(eval: &Evaluator) -> Lineage {
    let mut lineage = Lineage::new();
    let seed = KernelSpec::naive();
    let score = eval.evaluate(&seed);
    lineage.seed(seed, score, "seed x0: naive tiled attention");
    lineage
}

fn archive_ids(lineage: &Lineage) -> Vec<u64> {
    lineage.versions().iter().map(|c| c.id.0).collect()
}

/// Run a staged pipeline operator under the driver's per-step supervisor
/// loop (the same loop the legacy replicas run under).
fn pipeline_archive(
    op: &mut dyn VariationOperator,
    target_commits: usize,
    max_steps: usize,
) -> Vec<u64> {
    let eval = Evaluator::new(mha_suite());
    let mut lineage = seeded_lineage(&eval);
    let mut supervisor = Supervisor::new(SupervisorConfig::default());
    let mut steps = 0usize;
    while lineage.len() < target_commits + 1 && steps < max_steps {
        steps += 1;
        let outcome = op.step(&mut lineage, &eval, steps);
        if let Some(d) = supervisor.observe(&outcome, &lineage) {
            op.apply_directive(&d);
        }
    }
    archive_ids(&lineage)
}

#[test]
fn avo_pipeline_matches_monolith_byte_for_byte() {
    for seed in [5u64, 1234] {
        let eval = Evaluator::new(mha_suite());
        let mut lineage = seeded_lineage(&eval);
        let mut legacy = LegacyAvo::new(AvoConfig::default(), seed);
        let mut supervisor = Supervisor::new(SupervisorConfig::default());
        let mut steps = 0usize;
        while lineage.len() < 9 && steps < 40 {
            steps += 1;
            let outcome = legacy.step(&mut lineage, &eval, steps);
            if let Some(d) = supervisor.observe(&outcome, &lineage) {
                legacy.apply_directive(&d);
            }
        }
        let golden = archive_ids(&lineage);
        assert!(golden.len() > 1, "seed {seed}: monolith replica never committed");

        let mut agent = AvoAgent::new(AvoConfig::default(), seed);
        let staged = pipeline_archive(&mut agent, 8, 40);
        assert_eq!(staged, golden, "seed {seed}: staged AVO diverged from the monolith");
    }
}

#[test]
fn single_turn_pipeline_matches_monolith_byte_for_byte() {
    for seed in [3u64, 77] {
        let eval = Evaluator::new(mha_suite());
        let mut lineage = seeded_lineage(&eval);
        let mut legacy = LegacySingleTurn::new(seed);
        for step in 1..=40usize {
            let _ = legacy.step(&mut lineage, &eval, step);
        }
        let golden = archive_ids(&lineage);
        assert!(golden.len() > 1, "seed {seed}: monolith replica never committed");

        let eval = Evaluator::new(mha_suite());
        let mut lineage = seeded_lineage(&eval);
        let mut op = SingleTurnOperator::new(seed);
        for step in 1..=40usize {
            let _ = op.step(&mut lineage, &eval, step);
        }
        assert_eq!(
            archive_ids(&lineage),
            golden,
            "seed {seed}: staged single-turn diverged from the monolith"
        );
    }
}

#[test]
fn fixed_pipeline_matches_monolith_with_deterministic_elites() {
    for seed in [3u64, 19] {
        let eval = Evaluator::new(mha_suite());
        let mut lineage = seeded_lineage(&eval);
        let mut legacy = LegacyFixedPipeline::new(seed);
        for step in 1..=40usize {
            let _ = legacy.step(&mut lineage, &eval, step);
        }
        let golden = archive_ids(&lineage);
        assert!(golden.len() > 1, "seed {seed}: monolith replica never committed");

        let eval = Evaluator::new(mha_suite());
        let mut lineage = seeded_lineage(&eval);
        let mut op = FixedPipelineOperator::new(seed);
        for step in 1..=40usize {
            let _ = op.step(&mut lineage, &eval, step);
        }
        assert_eq!(
            archive_ids(&lineage),
            golden,
            "seed {seed}: staged fixed-pipeline diverged from the replica"
        );
    }
}

// ---------------------------------------------------------------------------
// Lookahead contract.
// ---------------------------------------------------------------------------

fn recorded_run(config: AvoConfig, seed: u64, steps: usize) -> (Vec<u64>, u64, u64, u64) {
    let rec = CountingBackend::new(Evaluator::new(mha_suite()));
    let mut lineage = seeded_lineage(rec.inner());
    let mut agent = AvoAgent::new(config, seed);
    for step in 1..=steps {
        let _ = agent.step(&mut lineage, &rec, step);
    }
    (archive_ids(&lineage), rec.calls(), rec.evals(), rec.max_width())
}

#[test]
fn lookahead_one_changes_nothing() {
    // `--lookahead 1` is the explicit spelling of the default: same
    // archive, same evaluate_batch call count, all batches singletons.
    let (ids_default, calls_default, evals_default, width_default) =
        recorded_run(AvoConfig::default(), 11, 25);
    let mut cfg = AvoConfig::default();
    cfg.lookahead = 1;
    let (ids_one, calls_one, evals_one, width_one) = recorded_run(cfg, 11, 25);
    assert_eq!(ids_one, ids_default);
    assert_eq!(calls_one, calls_default);
    assert_eq!(evals_one, evals_default);
    assert_eq!((width_default, width_one), (1, 1));
    // One-at-a-time: every evaluation is its own backend call.
    assert_eq!(calls_default, evals_default);
}

#[test]
fn lookahead_cuts_backend_calls_per_evaluation() {
    // The acceptance bar: with --lookahead 8 (+ speculative repair) the
    // agent issues measurably fewer evaluate_batch calls than the
    // one-at-a-time path needs for the same number of evaluations.
    // (benches/agent_stages.rs gates the same contract and threshold from
    // the bench side — keep the two in sync.)
    let mut cfg = AvoConfig::default();
    cfg.lookahead = 8;
    cfg.speculative_repair = true;
    let (ids, calls, evals, width) = recorded_run(cfg, 11, 25);
    assert!(ids.len() > 1, "lookahead run never committed");
    assert!(width >= 2, "no batch ever widened");
    assert!(
        (calls as f64) < 0.8 * (evals as f64),
        "expected >20% fewer backend calls than evaluations, got {calls}/{evals}"
    );
}
