//! Calibration acceptance bands: the simulator must land on the paper's
//! published numbers (DESIGN.md §Calibration).  These are the assertions
//! that make every figure/table reproduction meaningful.

use avo::baselines::{self, ablations};
use avo::kernelspec::KernelSpec;
use avo::score::{geomean, mha_suite, BenchConfig, Evaluator, SEQ_LENS, TOTAL_TOKENS};

fn sim_curve(spec: &KernelSpec, causal: bool) -> Vec<f64> {
    let ev = Evaluator::new(mha_suite());
    SEQ_LENS
        .iter()
        .map(|&n| {
            ev.report(spec, &BenchConfig::mha(TOTAL_TOKENS / n, n, causal)).tflops
        })
        .collect()
}

fn sim_geomean(spec: &KernelSpec, causal: bool) -> f64 {
    geomean(sim_curve(spec, causal).into_iter())
}

#[test]
fn evolved_genome_matches_avo_anchors_within_3pct() {
    for causal in [false, true] {
        let anchor = baselines::avo_measured(causal);
        for (sim, target) in sim_curve(&baselines::evolved_genome(), causal)
            .into_iter()
            .zip(anchor.tflops)
        {
            let err = (sim / target - 1.0).abs();
            assert!(err < 0.03, "causal={causal}: sim {sim:.1} vs anchor {target} ({err:.3})");
        }
    }
}

#[test]
fn headline_1668_reached() {
    // The paper's headline: up to 1668 TFLOPS (non-causal, 32k).
    let ev = Evaluator::new(mha_suite());
    let t = ev
        .report(
            &baselines::evolved_genome(),
            &BenchConfig::mha(1, 32768, false),
        )
        .tflops;
    assert!((t / 1668.0 - 1.0).abs() < 0.02, "headline sim {t:.1}");
}

#[test]
fn fa4_genome_within_8pct_of_measured_fa4() {
    // The FA4-design genome cannot express all of FA4's private tuning;
    // DESIGN.md documents the tolerance.  Causal must be tight (the paper
    // describes FA4's causal design precisely).
    for (causal, tol) in [(true, 0.04), (false, 0.08)] {
        let anchor = baselines::fa4_measured(causal);
        for (sim, target) in sim_curve(&baselines::fa4_genome(), causal)
            .into_iter()
            .zip(anchor.tflops)
        {
            let err = (sim / target - 1.0).abs();
            assert!(err < tol, "causal={causal}: {sim:.1} vs {target} ({err:.3})");
        }
    }
}

#[test]
fn ordering_evolved_above_cudnn_above_fa4() {
    // Who-wins ordering, causal (where the paper's gains are largest).
    let e = sim_geomean(&baselines::evolved_genome(), true);
    let c = sim_geomean(&baselines::cudnn_genome(), true);
    let f = sim_geomean(&baselines::fa4_genome(), true);
    assert!(e > c && c > f, "evolved {e:.1} cudnn {c:.1} fa4 {f:.1}");
}

#[test]
fn table1_branchless_rescale_deltas() {
    let (before, after) = ablations::branchless_rescale();
    let nc = 100.0 * (sim_geomean(&after, false) / sim_geomean(&before, false) - 1.0);
    let c = 100.0 * (sim_geomean(&after, true) / sim_geomean(&before, true) - 1.0);
    assert!((nc - 8.1).abs() < 1.0, "nc {nc:.2} vs +8.1");
    assert!((c - 1.6).abs() < 0.8, "c {c:.2} vs +1.6");
}

#[test]
fn table1_correction_overlap_deltas() {
    let (before, after) = ablations::correction_overlap();
    let nc = 100.0 * (sim_geomean(&after, false) / sim_geomean(&before, false) - 1.0);
    let c = 100.0 * (sim_geomean(&after, true) / sim_geomean(&before, true) - 1.0);
    assert!((nc - 1.1).abs() < 0.6, "nc {nc:.2} vs +1.1");
    assert!((c - 0.4).abs() < 0.5, "c {c:.2} vs +0.4");
}

#[test]
fn table1_register_rebalance_deltas() {
    let (before, after) = ablations::register_rebalance();
    let nc = 100.0 * (sim_geomean(&after, false) / sim_geomean(&before, false) - 1.0);
    let c = 100.0 * (sim_geomean(&after, true) / sim_geomean(&before, true) - 1.0);
    assert!((nc - 2.1).abs() < 0.8, "nc {nc:.2} vs +2.1");
    assert!(c.abs() < 0.8, "c {c:.2} vs ~0");
}

#[test]
fn fig3_gain_bands_causal() {
    // Causal: AVO beats cuDNN by +0.4..3.5% and FA4 by +5.0..10.5% per
    // config.  Simulated AVO vs the measured anchor curves must stay in
    // (generously padded) bands around those.
    let sim = sim_curve(&baselines::evolved_genome(), true);
    let cudnn = baselines::cudnn_measured(true);
    let fa4 = baselines::fa4_measured(true);
    for i in 0..4 {
        let vs_cudnn = 100.0 * (sim[i] / cudnn.tflops[i] - 1.0);
        let vs_fa4 = 100.0 * (sim[i] / fa4.tflops[i] - 1.0);
        assert!((-2.5..=5.0).contains(&vs_cudnn), "vs cudnn[{i}] {vs_cudnn:.1}");
        assert!((2.0..=12.0).contains(&vs_fa4), "vs fa4[{i}] {vs_fa4:.1}");
    }
}

#[test]
fn causal_below_noncausal_like_paper() {
    // The paper's curves: causal TFLOPS sit below non-causal at the same
    // config (flops convention + masked-path overheads).
    for spec in [baselines::evolved_genome(), baselines::fa4_genome()] {
        let nc = sim_geomean(&spec, false);
        let c = sim_geomean(&spec, true);
        assert!(c < nc, "causal {c:.1} !< noncausal {nc:.1}");
        assert!(c > nc * 0.85, "causal implausibly low: {c:.1} vs {nc:.1}");
    }
}

#[test]
fn throughput_rises_with_seq_len() {
    // Both regimes: longer sequences amortize per-tile overheads (the
    // paper's curves rise from 4k to 32k).
    for causal in [false, true] {
        let curve = sim_curve(&baselines::evolved_genome(), causal);
        for w in curve.windows(2) {
            assert!(w[1] > w[0] * 0.995, "curve not rising: {curve:?}");
        }
    }
}
