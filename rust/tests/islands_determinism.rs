//! Island-model reproducibility contract: archive contents are a pure
//! function of (config, seed genome) — identical across repeated runs,
//! independent of worker-thread count, and distinct across run seeds.

use avo::coordinator::{EvolutionDriver, RunConfig, RunReport};
use avo::islands::MigrationPolicy;

fn island_config(
    seed: u64,
    islands: usize,
    workers: usize,
    policy: MigrationPolicy,
) -> RunConfig {
    let mut cfg = RunConfig {
        seed,
        target_commits: 6,
        max_steps: 30,
        ..RunConfig::default()
    };
    cfg.topology.islands = islands;
    cfg.topology.workers = workers;
    cfg.topology.migration = policy;
    cfg.topology.migrate_every = 2;
    cfg
}

/// The full per-island commit-id sequences (stronger than comparing heads:
/// ids are content hashes chained through parents, so equality here means
/// byte-identical archives).
fn archives(report: &RunReport) -> Vec<Vec<u64>> {
    report
        .islands
        .iter()
        .map(|i| i.lineage.versions().iter().map(|c| c.id.0).collect())
        .collect()
}

fn heads(report: &RunReport) -> Vec<Option<u64>> {
    report
        .islands
        .iter()
        .map(|i| i.lineage.head().map(|c| c.id.0))
        .collect()
}

#[test]
fn same_seed_same_archives_every_policy() {
    for policy in [
        MigrationPolicy::Ring,
        MigrationPolicy::BroadcastBest,
        MigrationPolicy::RandomPairs,
    ] {
        let a = EvolutionDriver::new(island_config(21, 3, 2, policy)).run();
        let b = EvolutionDriver::new(island_config(21, 3, 2, policy)).run();
        assert_eq!(heads(&a), heads(&b), "heads diverged under {policy}");
        assert_eq!(archives(&a), archives(&b), "archives diverged under {policy}");
        assert_eq!(a.steps, b.steps);
    }
}

#[test]
fn archives_independent_of_worker_count() {
    let policy = MigrationPolicy::Ring;
    let serial = EvolutionDriver::new(island_config(9, 4, 1, policy)).run();
    let two = EvolutionDriver::new(island_config(9, 4, 2, policy)).run();
    let wide = EvolutionDriver::new(island_config(9, 4, 8, policy)).run();
    assert_eq!(archives(&serial), archives(&two));
    assert_eq!(archives(&serial), archives(&wide));
    assert_eq!(heads(&serial), heads(&wide));
    assert!((serial.lineage.best_geomean() - wide.lineage.best_geomean()).abs() < 1e-12);
}

#[test]
fn different_seeds_diverge() {
    let a = EvolutionDriver::new(island_config(1, 3, 2, MigrationPolicy::Ring)).run();
    let b = EvolutionDriver::new(island_config(2, 3, 2, MigrationPolicy::Ring)).run();
    assert_ne!(
        archives(&a),
        archives(&b),
        "distinct run seeds must explore distinct trajectories"
    );
}

#[test]
fn islands_explore_distinct_trajectories_within_a_run() {
    let report =
        EvolutionDriver::new(island_config(5, 3, 3, MigrationPolicy::Ring)).run();
    let ar = archives(&report);
    // All islands share the seed commit (same genome, no parent)...
    assert_eq!(ar[0][0], ar[1][0]);
    assert_eq!(ar[0][0], ar[2][0]);
    // ...but their operator streams are independent, so the full archives
    // must not be identical three ways.
    assert!(
        !(ar[0] == ar[1] && ar[1] == ar[2]),
        "independent island streams collapsed to one trajectory"
    );
}

#[test]
fn warm_started_run_reproduces_cold_archive() {
    // Save a run's evaluation cache, then warm-start the same config from
    // it: the archive must be byte-identical to the cold run while the
    // cache does the scoring work (nonzero hits, no misses).
    let dir = std::env::temp_dir().join(format!("avo_det_warm_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut save_cfg = island_config(33, 3, 2, MigrationPolicy::Ring);
    save_cfg.eval_cache_path = Some(dir.join(avo::eval::CACHE_FILE));
    let cold = EvolutionDriver::new(save_cfg).run();

    let mut warm_cfg = island_config(33, 3, 2, MigrationPolicy::Ring);
    warm_cfg.warm_start = Some(dir.clone());
    let warm = EvolutionDriver::new(warm_cfg).run();

    assert_eq!(archives(&cold), archives(&warm));
    assert_eq!(heads(&cold), heads(&warm));
    assert!(warm.metrics.counter("eval_cache_hits") > 0);
    assert_eq!(warm.metrics.counter("eval_cache_misses"), 0);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn n_island_run_matches_or_beats_each_member_island() {
    // The reported global best is by construction the max over islands.
    let report =
        EvolutionDriver::new(island_config(17, 3, 2, MigrationPolicy::BroadcastBest)).run();
    for isl in &report.islands {
        assert!(report.lineage.best_geomean() >= isl.lineage.best_geomean() - 1e-12);
    }
    assert!(report.metrics.counter("eval_cache_hits") > 0);
}
