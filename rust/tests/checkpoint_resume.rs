//! Kill-and-resume suite for the durable run ledger (`--checkpoint-dir`
//! / `--resume`).
//!
//! The contract under test: a run interrupted between generations and
//! resumed from its ledger finishes with an archive and trajectory
//! byte-identical to the same-seed run that was never interrupted.  The
//! interruption is `halt_after_checkpoints`, which returns right after
//! the n-th atomic ledger commit — exactly the on-disk state a SIGKILL
//! between generations would leave (the rename either happened or it
//! didn't; there is no torn snapshot).  Both checkpointable regimes are
//! covered: barrier mode with multiple islands, and steady-state on the
//! serial (`--island-workers 1`) scheduler.  Corrupt, mismatched, and
//! wrongly-shaped checkpoints must be rejected loudly, never resumed
//! into a silently different search.

use std::path::PathBuf;

use avo::coordinator::{EvolutionDriver, RunConfig, SchedulingMode};
use avo::supervisor::checkpoint::{self, CHECKPOINT_FILE};

fn tempdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("avo_resume_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Two barrier islands, one commit per epoch: several generations (and
/// so several ledger commits) before the run finishes.
fn barrier_cfg(seed: u64) -> RunConfig {
    let mut cfg = RunConfig {
        seed,
        target_commits: 3,
        max_steps: 15,
        workload: "mha".to_string(),
        ..RunConfig::default()
    };
    cfg.topology.islands = 2;
    cfg.topology.migrate_every = 1;
    cfg
}

/// The same search on the steady-state serial scheduler — the one
/// steady regime whose archives are seed-deterministic, and therefore
/// the one the ledger accepts.
fn steady_cfg(seed: u64) -> RunConfig {
    let mut cfg = barrier_cfg(seed);
    cfg.topology.scheduling = SchedulingMode::SteadyState;
    cfg.topology.workers = 1;
    cfg
}

/// Interrupt `cfg`'s run after `halt_after` ledger commits, resume it
/// from the same directory, and assert the finished archive and
/// trajectory are byte-identical to the uninterrupted same-seed run.
fn assert_kill_and_resume_is_byte_identical(
    tag: &str,
    make_cfg: &dyn Fn(u64) -> RunConfig,
    halt_after: usize,
) {
    let dir = tempdir(tag);
    let ckpt = dir.join("ckpt");

    // Ground truth: the same seed, never interrupted, no ledger.
    let mut cold_cfg = make_cfg(23);
    cold_cfg.lineage_path = Some(dir.join("cold_lineage.json"));
    let cold = EvolutionDriver::new(cold_cfg).run();
    let cold_bytes = std::fs::read(dir.join("cold_lineage.json")).unwrap();
    assert!(!cold_bytes.is_empty());

    // Interrupted: the ledger commits every generation, and the run
    // returns right after commit `halt_after` — a SIGKILL stand-in.
    let mut halted_cfg = make_cfg(23);
    halted_cfg.checkpoint_dir = Some(ckpt.clone());
    halted_cfg.halt_after_checkpoints = Some(halt_after);
    halted_cfg.telemetry.journal = Some(dir.join("halted_journal.jsonl"));
    let halted = EvolutionDriver::new(halted_cfg).run();
    assert!(
        halted.lineage.len() < cold.lineage.len(),
        "{tag}: the halted run was not actually interrupted"
    );
    let snap_text = std::fs::read_to_string(ckpt.join(CHECKPOINT_FILE)).unwrap();
    let snap = avo::json::parse(&snap_text).unwrap();
    assert_eq!(
        snap.get("generation").and_then(avo::json::Json::as_u64),
        Some(halt_after as u64),
        "{tag}: ledger left the wrong generation behind"
    );
    let halted_journal = std::fs::read_to_string(dir.join("halted_journal.jsonl")).unwrap();
    assert!(
        halted_journal.contains("\"event\":\"run_checkpointed\""),
        "{tag}: journal missing run_checkpointed"
    );

    // The snapshot carries the search config: `--resume <dir>` needs no
    // flags repeated.  Overlay onto defaults and spot-check the subset.
    let mut overlaid = RunConfig::default();
    checkpoint::overlay_config(&ckpt, &mut overlaid).unwrap();
    assert_eq!(overlaid.seed, 23);
    assert_eq!(overlaid.target_commits, 3);
    assert_eq!(overlaid.topology.islands, 2);
    assert_eq!(overlaid.topology.scheduling, make_cfg(23).topology.scheduling);

    // Resume to completion from the ledger.
    let mut resumed_cfg = make_cfg(23);
    resumed_cfg.checkpoint_dir = Some(ckpt.clone());
    resumed_cfg.resume = true;
    resumed_cfg.lineage_path = Some(dir.join("resumed_lineage.json"));
    resumed_cfg.telemetry.journal = Some(dir.join("resumed_journal.jsonl"));
    let resumed = EvolutionDriver::new(resumed_cfg).run();

    let resumed_bytes = std::fs::read(dir.join("resumed_lineage.json")).unwrap();
    assert_eq!(
        cold_bytes, resumed_bytes,
        "{tag}: killed+resumed archive diverges from the uninterrupted run"
    );
    assert_eq!(
        cold.lineage.trajectory_json(true).pretty(),
        resumed.lineage.trajectory_json(true).pretty(),
        "{tag}: killed+resumed trajectory diverges from the uninterrupted run"
    );
    // The resumed run warm-starts from the ledger's cache snapshot: the
    // generations before the kill are never re-simulated.
    assert!(
        resumed.metrics.counter("eval_cache_warm_entries") > 0,
        "{tag}: resume did not warm-start from the checkpoint cache"
    );
    let resumed_journal =
        std::fs::read_to_string(dir.join("resumed_journal.jsonl")).unwrap();
    assert!(
        resumed_journal.contains("\"event\":\"run_resumed\""),
        "{tag}: journal missing run_resumed"
    );
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn barrier_kill_and_resume_is_byte_identical() {
    assert_kill_and_resume_is_byte_identical("barrier", &barrier_cfg, 1);
}

#[test]
fn steady_serial_kill_and_resume_is_byte_identical() {
    assert_kill_and_resume_is_byte_identical("steady", &steady_cfg, 2);
}

#[test]
#[should_panic(expected = "--resume:")]
fn resume_rejects_corrupt_checkpoint() {
    let dir = tempdir("corrupt");
    std::fs::write(dir.join(CHECKPOINT_FILE), "{not json").unwrap();
    let mut cfg = barrier_cfg(23);
    cfg.checkpoint_dir = Some(dir);
    cfg.resume = true;
    EvolutionDriver::new(cfg).run();
}

#[test]
#[should_panic(expected = "fingerprint mismatch")]
fn resume_rejects_checkpoint_from_different_workload() {
    let dir = tempdir("fpr");
    // Leave a real mha checkpoint behind...
    let mut halted = RunConfig {
        seed: 29,
        target_commits: 2,
        max_steps: 10,
        workload: "mha".to_string(),
        ..RunConfig::default()
    };
    halted.checkpoint_dir = Some(dir.clone());
    halted.halt_after_checkpoints = Some(1);
    EvolutionDriver::new(halted).run();
    // ...then try to resume a gqa:4 search from it: the fingerprint
    // (suite ^ machine model) no longer matches and the load must fail.
    let mut cfg = RunConfig {
        seed: 29,
        target_commits: 2,
        max_steps: 10,
        workload: "gqa:4".to_string(),
        ..RunConfig::default()
    };
    cfg.checkpoint_dir = Some(dir);
    cfg.resume = true;
    EvolutionDriver::new(cfg).run();
}

#[test]
#[should_panic(expected = "islands, this run wants")]
fn resume_rejects_island_count_mismatch() {
    let dir = tempdir("shape");
    let mut halted = barrier_cfg(31);
    halted.checkpoint_dir = Some(dir.clone());
    halted.halt_after_checkpoints = Some(1);
    EvolutionDriver::new(halted).run();
    let mut cfg = barrier_cfg(31);
    cfg.topology.islands = 3;
    cfg.checkpoint_dir = Some(dir);
    cfg.resume = true;
    EvolutionDriver::new(cfg).run();
}

#[test]
#[should_panic(expected = "--checkpoint-dir requires --island-workers 1")]
fn steady_multi_worker_checkpointing_is_rejected() {
    let mut cfg = steady_cfg(37);
    cfg.topology.workers = 4;
    cfg.checkpoint_dir = Some(tempdir("multiworker"));
    EvolutionDriver::new(cfg).run();
}
