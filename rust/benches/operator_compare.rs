//! Bench for the Fig. 1 operator comparison: times one variation step of
//! each operator (the per-step cost of agentic vs single-turn vs
//! fixed-pipeline variation) and a short equal-budget race.

use avo::agent::{
    AvoAgent, AvoConfig, FixedPipelineOperator, SingleTurnOperator, VariationOperator,
};
use avo::benchkit::Bench;
use avo::evolution::Lineage;
use avo::kernelspec::KernelSpec;
use avo::score::{mha_suite, Evaluator};

fn seeded_lineage(eval: &Evaluator) -> Lineage {
    let mut lineage = Lineage::new();
    let seed = KernelSpec::naive();
    let score = eval.evaluate(&seed);
    lineage.seed(seed, score, "seed");
    lineage
}

fn main() {
    let eval = Evaluator::new(mha_suite());
    let mut b = Bench::new("operator_compare");

    b.case("step/avo", || {
        let mut lineage = seeded_lineage(&eval);
        let mut op = AvoAgent::new(AvoConfig::default(), 1);
        op.step(&mut lineage, &eval, 1)
    });
    b.case("step/single_turn", || {
        let mut lineage = seeded_lineage(&eval);
        let mut op = SingleTurnOperator::new(1);
        op.step(&mut lineage, &eval, 1)
    });
    b.case("step/fixed_pipeline", || {
        let mut lineage = seeded_lineage(&eval);
        let mut op = FixedPipelineOperator::new(1);
        op.step(&mut lineage, &eval, 1)
    });

    b.case("race_120_evals/avo", || {
        let mut lineage = seeded_lineage(&eval);
        let mut op = AvoAgent::new(AvoConfig::default(), 5);
        let (mut used, mut step) = (0, 0);
        while used < 120 {
            step += 1;
            used += op.step(&mut lineage, &eval, step).evaluations.max(1);
        }
        lineage.best_geomean()
    });
    b.finish();
}
