//! Island-model scaling: wall-clock and best-geomean of one sequential
//! lineage vs an N-island archipelago at the SAME total variation-step
//! budget (the N-island run splits the budget N ways, so any win comes
//! from parallel wall-clock, migration, and cache-level deduplication —
//! not from extra evaluations).
//!
//!   cargo bench --bench island_scaling
//!   AVO_BENCH_QUICK=1 cargo bench --bench island_scaling   # CI-sized

use avo::benchkit::Bench;
use avo::coordinator::{EvolutionDriver, RunConfig, RunReport};
use avo::islands::MigrationPolicy;

const TOTAL_STEPS: usize = 96;
const SEED: u64 = 42;

fn config(islands: usize, policy: MigrationPolicy) -> RunConfig {
    let mut cfg = RunConfig {
        seed: SEED,
        // Budget purely by steps: the commit target never binds.
        target_commits: usize::MAX / 2,
        max_steps: TOTAL_STEPS / islands,
        ..RunConfig::default()
    };
    cfg.topology.islands = islands;
    cfg.topology.migration = policy;
    cfg.topology.migrate_every = 2;
    cfg
}

fn run(islands: usize, policy: MigrationPolicy) -> RunReport {
    EvolutionDriver::new(config(islands, policy)).run()
}

fn main() {
    let mut b = Bench::new("island_scaling").with_iters(1, 3);

    b.case("1_island_96_steps", || run(1, MigrationPolicy::Ring));
    b.case("4_islands_24_steps_ring", || run(4, MigrationPolicy::Ring));
    b.case("4_islands_24_steps_broadcast", || {
        run(4, MigrationPolicy::BroadcastBest)
    });
    b.finish();

    // Quality at equal evaluation budget (one representative run each;
    // runs are deterministic, so this is the value every iteration saw).
    let single = run(1, MigrationPolicy::Ring);
    let ring = run(4, MigrationPolicy::Ring);
    let broadcast = run(4, MigrationPolicy::BroadcastBest);
    println!("\n== equal-budget quality ({TOTAL_STEPS} total steps, seed {SEED}) ==");
    for (name, r) in [
        ("1 island", &single),
        ("4 islands / ring", &ring),
        ("4 islands / broadcast_best", &broadcast),
    ] {
        println!(
            "  {name:<28} best geomean {:8.1} TFLOPS  ({} evaluations, \
             cache {} hits / {} misses)",
            r.lineage.best_geomean(),
            r.metrics.counter("evaluations"),
            r.metrics.counter("eval_cache_hits"),
            r.metrics.counter("eval_cache_misses"),
        );
    }
    let best_island = ring.lineage.best_geomean().max(broadcast.lineage.best_geomean());
    println!(
        "  island best {} single-lineage best ({:.1} vs {:.1})",
        if best_island >= single.lineage.best_geomean() { ">=" } else { "<" },
        best_island,
        single.lineage.best_geomean()
    );
    assert!(
        ring.metrics.counter("eval_cache_hits") > 0
            && broadcast.metrics.counter("eval_cache_hits") > 0,
        "N-island runs must deduplicate through the shared EvalCache"
    );
}
