//! Staged agent-runtime microbenchmarks, plus the lookahead batching gate.
//!
//! Times short AVO runs under the default one-at-a-time configuration and
//! under refinement-lookahead + speculative-repair batching, then prints
//! the per-stage wall-clock breakdown from the merged [`AgentTrace`].
//!
//! Doubles as a CI gate (like `benches/hotpath.rs`): after timing, it
//! asserts the acceptance bar for the batching work — at `--lookahead 8`
//! with speculative repair the agent must issue measurably fewer
//! `evaluate_batch` calls than the one-at-a-time path needs for the same
//! number of evaluations, while the default configuration must keep the
//! strict one-call-per-evaluation shape that byte-for-byte archive parity
//! rests on.

use avo::agent::{AgentTrace, AvoAgent, AvoConfig, VariationOperator};
use avo::benchkit::Bench;
use avo::eval::CountingBackend;
use avo::evolution::Lineage;
use avo::kernelspec::KernelSpec;
use avo::score::{mha_suite, Evaluator};

/// Run `steps` AVO variation steps; return (commits, merged trace, stats).
fn run(config: AvoConfig, seed: u64, steps: usize) -> (usize, AgentTrace, u64, u64, u64) {
    let rec = CountingBackend::new(Evaluator::new(mha_suite()));
    let mut lineage = Lineage::new();
    let seed_spec = KernelSpec::naive();
    let score = rec.inner().evaluate(&seed_spec);
    lineage.seed(seed_spec, score, "seed x0: naive tiled attention");
    let mut agent = AvoAgent::new(config, seed);
    let mut trace = AgentTrace::default();
    for step in 1..=steps {
        let outcome = agent.step(&mut lineage, &rec, step);
        trace.merge(&outcome.trace);
    }
    (lineage.len(), trace, rec.calls(), rec.evals(), rec.max_width())
}

fn lookahead_config(k: usize) -> AvoConfig {
    let mut cfg = AvoConfig::default();
    cfg.lookahead = k;
    cfg.speculative_repair = true;
    cfg
}

fn main() {
    let mut b = Bench::new("agent_stages").with_iters(1, 5);
    b.case("avo_10_steps_one_at_a_time", || run(AvoConfig::default(), 42, 10));
    b.case("avo_10_steps_lookahead4", || run(lookahead_config(4), 42, 10));
    b.case("avo_10_steps_lookahead8", || run(lookahead_config(8), 42, 10));
    b.finish();

    // Stage breakdown of a representative run (observability, not a gate).
    // ms/eval normalizes each stage's wall-clock by the evaluations the run
    // performed, so stage costs stay comparable across configurations with
    // different batching shapes.
    let (_, trace, _, run_evals, _) = run(AvoConfig::default(), 7, 15);
    println!("stage breakdown (15 default steps, {run_evals} evals):");
    for (stage, stat) in &trace.stages {
        let ms = stat.nanos as f64 / 1e6;
        println!(
            "  {stage:<10} {:>5} runs  {ms:>8.2} ms  {:>8.4} ms/eval",
            stat.runs,
            ms / run_evals.max(1) as f64
        );
    }

    // == batching gate (CI) ==
    // The same contract (and the 0.8 call-reduction threshold) is pinned
    // suite-side by tests/operator_parity.rs::lookahead_one_changes_nothing
    // and ::lookahead_cuts_backend_calls_per_evaluation — keep the two in
    // sync.  This copy is the *bench-side* gate the acceptance criteria
    // name: a batching regression fails `cargo bench --bench agent_stages`,
    // not just the numbers.
    let (_, trace, calls, evals, width) = run(AvoConfig::default(), 42, 15);
    assert_eq!(width, 1, "default flags must never widen a batch");
    assert_eq!(calls, evals, "default flags: one backend call per evaluation");
    assert_eq!(trace.eval_batches, calls, "trace must account every backend call");
    assert_eq!(trace.evals, evals, "trace must account every evaluation");

    let (commits, trace8, calls8, evals8, width8) = run(lookahead_config(8), 42, 15);
    assert!(commits > 1, "lookahead run never committed");
    assert!(width8 >= 2, "lookahead never widened a batch");
    assert!(
        (calls8 as f64) < 0.8 * (evals8 as f64),
        "lookahead 8 + speculative repair must cut backend calls by >20% \
         per evaluation: {calls8} calls / {evals8} evals"
    );
    assert_eq!(trace8.eval_batches, calls8);
    println!(
        "batching gate OK: one-at-a-time {calls}/{evals} calls/evals, \
         lookahead8 {calls8}/{evals8} (max width {width8})"
    );
}
