//! Bench for Figure 3: times the full MHA-suite evaluation of the evolved
//! kernel and each baseline genome (the end-to-end scoring path behind
//! every Fig. 3 cell), then prints the regenerated figure rows.

use avo::baselines;
use avo::benchkit::Bench;
use avo::kernelspec::KernelSpec;
use avo::repro;
use avo::score::{mha_suite, Evaluator};

fn main() {
    let eval = Evaluator::new(mha_suite());
    let mut b = Bench::new("fig3_mha");
    for (name, spec) in [
        ("evolved", baselines::evolved_genome()),
        ("fa4_design", baselines::fa4_genome()),
        ("cudnn_class", baselines::cudnn_genome()),
        ("naive_seed", KernelSpec::naive()),
    ] {
        b.case(&format!("suite_eval/{name}"), || eval.evaluate(&spec));
    }
    b.case("fig3_render", || repro::fig3(&baselines::evolved_genome()));
    b.finish();
    println!("\n{}", repro::fig3(&baselines::evolved_genome()));
}
