//! Durable run ledger: commit latency and kill/resume determinism
//! ([`avo::supervisor::checkpoint`]).
//!
//! Two claims are gated:
//!
//! * a generation commit (serialize the full snapshot — archives,
//!   operator/supervisor residue, PRNG cursors — write `.tmp`, rename)
//!   is cheap enough to run every generation: mean commit latency stays
//!   under [`COMMIT_BUDGET_MS`] even at 8 islands;
//! * the ledger is *correct*: a run killed between generations
//!   (`halt_after_checkpoints`) and resumed finishes byte-identical to
//!   the uninterrupted same-seed run, while re-simulating nothing the
//!   interrupted run already paid for (the resume warm-starts from the
//!   ledger's cache snapshot).
//!
//!   cargo bench --bench checkpoint_resume
//!   AVO_BENCH_QUICK=1 cargo bench --bench checkpoint_resume   # CI-sized

use std::path::PathBuf;
use std::time::{Duration, Instant};

use avo::benchkit::Bench;
use avo::coordinator::{EvolutionDriver, RunConfig, SchedulingMode};
use avo::evolution::Lineage;
use avo::json::Json;
use avo::kernelspec::KernelSpec;
use avo::score::{mha_suite, Evaluator};
use avo::supervisor::checkpoint::{IslandState, RunLedger, RunSnapshot};

/// Mean per-generation commit latency ceiling, in milliseconds.  A
/// snapshot is a few tens of KB of canonical JSON plus one rename; if
/// this ever creeps toward real generation cost (seconds), per-epoch
/// checkpointing has become the bottleneck and the gate fails.
const COMMIT_BUDGET_MS: f64 = 25.0;

fn tempdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("avo_bench_ckpt_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

struct Sizing {
    commits: usize,
    steps: usize,
    ledger_commits: usize,
}

fn sizing() -> Sizing {
    if std::env::var("AVO_BENCH_QUICK").is_ok() {
        Sizing { commits: 3, steps: 15, ledger_commits: 40 }
    } else {
        Sizing { commits: 5, steps: 25, ledger_commits: 200 }
    }
}

/// A realistic snapshot: `islands` seeded archives plus PRNG/interval
/// residue — the payload a barrier generation commits.
fn synthetic_snapshot(islands: usize) -> RunSnapshot {
    let eval = Evaluator::new(mha_suite());
    let spec = KernelSpec::naive();
    let score = eval.evaluate(&spec);
    RunSnapshot {
        mode: SchedulingMode::Barrier,
        generation: 7,
        mig_rng: [1, 2, 3, 4],
        islands: (0..islands)
            .map(|id| {
                let mut lineage = Lineage::new();
                lineage.seed(spec.clone(), score.clone(), "seed x0");
                IslandState {
                    id,
                    lineage,
                    operator: Json::Null,
                    supervisor: Json::obj([]),
                    steps: 11,
                    migrate_every: 4,
                    stall_epochs: 0,
                    best_at_barrier: 1.25,
                    interventions: Vec::new(),
                }
            })
            .collect(),
        steady: None,
    }
}

/// Mean wall-clock of one atomic ledger commit at the given island count.
fn commit_latency(islands: usize, commits: usize) -> Duration {
    let dir = tempdir(&format!("commit_{islands}"));
    let cfg = RunConfig::default();
    let mut ledger = RunLedger::create(&dir, &cfg, 0xBEEF).unwrap();
    let snap = synthetic_snapshot(islands);
    let started = Instant::now();
    for _ in 0..commits {
        ledger.commit(&snap).unwrap();
    }
    let mean = started.elapsed() / commits as u32;
    std::fs::remove_dir_all(dir).ok();
    mean
}

fn search_cfg(seed: u64) -> RunConfig {
    let s = sizing();
    let mut cfg = RunConfig {
        seed,
        target_commits: s.commits,
        max_steps: s.steps,
        ..RunConfig::default()
    };
    cfg.topology.islands = 2;
    cfg.topology.migrate_every = 1;
    cfg
}

struct ResumeOutcome {
    identical: bool,
    warm_entries: u64,
    cold_wall: Duration,
    ledgered_wall: Duration,
}

/// Cold run vs killed-after-one-generation + resumed run, same seed.
fn kill_and_resume() -> ResumeOutcome {
    let dir = tempdir("resume");
    let ckpt = dir.join("ckpt");

    let mut cold_cfg = search_cfg(47);
    cold_cfg.lineage_path = Some(dir.join("cold_lineage.json"));
    let started = Instant::now();
    EvolutionDriver::new(cold_cfg).run();
    let cold_wall = started.elapsed();

    let mut halted_cfg = search_cfg(47);
    halted_cfg.checkpoint_dir = Some(ckpt.clone());
    halted_cfg.halt_after_checkpoints = Some(1);
    let started = Instant::now();
    EvolutionDriver::new(halted_cfg).run();

    let mut resumed_cfg = search_cfg(47);
    resumed_cfg.checkpoint_dir = Some(ckpt);
    resumed_cfg.resume = true;
    resumed_cfg.lineage_path = Some(dir.join("resumed_lineage.json"));
    let resumed = EvolutionDriver::new(resumed_cfg).run();
    // Interrupted halves together, ledger commits included.
    let ledgered_wall = started.elapsed();

    let identical = std::fs::read(dir.join("cold_lineage.json")).unwrap()
        == std::fs::read(dir.join("resumed_lineage.json")).unwrap();
    let warm_entries = resumed.metrics.counter("eval_cache_warm_entries");
    std::fs::remove_dir_all(dir).ok();
    ResumeOutcome { identical, warm_entries, cold_wall, ledgered_wall }
}

fn main() {
    let s = sizing();
    let mut b = Bench::new("checkpoint_resume").with_iters(1, 2);
    b.case("ledger_commit_2i", || commit_latency(2, s.ledger_commits));
    b.case("ledger_commit_8i", || commit_latency(8, s.ledger_commits));
    b.finish();

    println!("\n== durable run ledger: commit latency ==");
    let mut worst = Duration::ZERO;
    for islands in [1usize, 2, 4, 8] {
        let mean = commit_latency(islands, s.ledger_commits);
        worst = worst.max(mean);
        println!("  {islands} island(s): {:8.3} ms / commit", mean.as_secs_f64() * 1e3);
    }
    // Gate 1: per-generation commits stay ledger-cheap.
    assert!(
        worst.as_secs_f64() * 1e3 <= COMMIT_BUDGET_MS,
        "ledger commit latency {:.3} ms exceeds the {COMMIT_BUDGET_MS} ms budget",
        worst.as_secs_f64() * 1e3,
    );

    println!("\n== kill one generation in, resume, compare to uninterrupted ==");
    let out = kill_and_resume();
    println!(
        "  cold {:7.1} ms | killed+resumed {:7.1} ms | warm-start entries {}",
        out.cold_wall.as_secs_f64() * 1e3,
        out.ledgered_wall.as_secs_f64() * 1e3,
        out.warm_entries,
    );
    // Gate 2: resume determinism — the whole point of the ledger.
    assert!(out.identical, "killed+resumed archive diverges from the uninterrupted run");
    assert!(
        out.warm_entries > 0,
        "resume re-simulated the interrupted run's evaluations instead of warm-starting"
    );
}
