//! Bench for Figure 4: times GQA-suite evaluation (both group sizes) and
//! the 30-minute-analog transfer run, then prints the regenerated figure.

use avo::baselines;
use avo::benchkit::Bench;
use avo::coordinator::{EvolutionDriver, RunConfig};
use avo::repro;
use avo::score::{gqa_suite, Evaluator};

fn main() {
    let mut b = Bench::new("fig4_gqa");
    for kv in [4u32, 8] {
        let eval = Evaluator::new(gqa_suite(kv));
        b.case(&format!("suite_eval/g{}", 32 / kv), || {
            eval.evaluate(&baselines::evolved_genome())
        });
    }
    b.case("transfer_run/g8", || {
        let driver = EvolutionDriver::new(RunConfig { seed: 43, ..RunConfig::default() });
        driver.transfer_to_gqa(baselines::evolved_genome(), 4)
    });
    b.finish();
    println!("\n{}", repro::fig4(&baselines::evolved_genome()));
}
