//! Scheduler saturation under an adversarial fleet: barrier epochs vs
//! steady-state island scheduling when one island worker is a 4x
//! straggler ([`avo::eval::SkewBackend`] binds each worker thread to a
//! latency multiplier, scores untouched).
//!
//! Barrier mode joins every island at each migration barrier, so the
//! fast worker idles while the straggler finishes its quota; the
//! steady-state work queue hands the fast worker another island
//! instead.  The gate pins the headline claim: steady-state cuts the
//! island-worker idle fraction by at least 40% relative to barrier mode
//! under 4x skew.
//!
//!   cargo bench --bench archipelago_steadystate
//!   AVO_BENCH_QUICK=1 cargo bench --bench archipelago_steadystate   # CI-sized
//!
//! Wall-clock here is dominated by injected sleeps, so iteration counts
//! stay at 1 x 2; the interesting output is the idle-fraction table.

use std::time::Duration;

use avo::benchkit::Bench;
use avo::coordinator::{RunConfig, RunReport, SchedulingMode};
use avo::eval::{SimBackend, SkewBackend};
use avo::islands::Archipelago;
use avo::score::Evaluator;

const SEED: u64 = 42;
/// One slot per island worker: a 1x worker and a 4x straggler.
const SKEW: [u32; 2] = [1, 4];

struct Sizing {
    commits: usize,
    steps: usize,
    delay_ms: u64,
}

fn sizing() -> Sizing {
    if std::env::var("AVO_BENCH_QUICK").is_ok() {
        Sizing { commits: 3, steps: 12, delay_ms: 2 }
    } else {
        Sizing { commits: 6, steps: 30, delay_ms: 3 }
    }
}

fn run_mode(mode: SchedulingMode) -> RunReport {
    let s = sizing();
    let mut cfg = RunConfig {
        seed: SEED,
        target_commits: s.commits,
        max_steps: s.steps,
        ..RunConfig::default()
    };
    cfg.topology.islands = 6;
    cfg.topology.workers = SKEW.len();
    cfg.topology.migrate_every = 2;
    cfg.topology.scheduling = mode;
    let workload = cfg.workload();
    let eval = Evaluator::for_workload(&*workload);
    // Inner sim stays serial: the injected skew IS the latency model.
    let backend = SkewBackend::new(
        SimBackend::new(eval, 1),
        Duration::from_millis(s.delay_ms),
        SKEW.to_vec(),
    );
    Archipelago::new(cfg).run_from_with(
        backend,
        workload.seed_genome(),
        &workload.seed_message(),
    )
}

/// Island-worker idle fraction from the run's saturation counters.
fn idle_fraction(report: &RunReport) -> f64 {
    let capacity = report.metrics.counter("island_capacity_ms");
    let busy = report.metrics.counter("island_busy_ms").min(capacity);
    assert!(capacity > 0, "threaded run reported no island capacity");
    1.0 - busy as f64 / capacity as f64
}

fn main() {
    let mut b = Bench::new("archipelago_steadystate").with_iters(1, 2);
    b.case("barrier_4x_skew", || run_mode(SchedulingMode::Barrier));
    b.case("steady_state_4x_skew", || run_mode(SchedulingMode::SteadyState));
    b.finish();

    let barrier = run_mode(SchedulingMode::Barrier);
    let steady = run_mode(SchedulingMode::SteadyState);
    let barrier_idle = idle_fraction(&barrier);
    let steady_idle = idle_fraction(&steady);

    println!("\n== island-worker saturation under 4x latency skew ==");
    for (name, report, idle) in [
        ("barrier", &barrier, barrier_idle),
        ("steady_state", &steady, steady_idle),
    ] {
        println!(
            "  {name:<13} idle {:5.1}%  (busy {} ms / capacity {} ms, best {:.1} TFLOPS)",
            100.0 * idle,
            report.metrics.counter("island_busy_ms"),
            report.metrics.counter("island_capacity_ms"),
            report.lineage.best_geomean(),
        );
        println!("    {}", report.summary());
    }
    let cut = if barrier_idle > 0.0 { 1.0 - steady_idle / barrier_idle } else { 0.0 };
    println!("  relative idle cut: {:.0}%", 100.0 * cut);

    // The PR gate: steady-state must cut island idle by >= 40% relative
    // to barrier scheduling when one worker runs 4x slow.
    assert!(
        steady_idle <= 0.6 * barrier_idle,
        "steady-state idle {:.1}% did not cut barrier idle {:.1}% by >= 40%",
        100.0 * steady_idle,
        100.0 * barrier_idle,
    );
}
