//! Warm-fleet throughput and fleet-wide dedup for the distributed
//! eval-cache fabric ([`avo::eval::remote`]).
//!
//! Each worker hosts a `Cached<Sim>` stack; freshly computed entries
//! gossip back to the coordinator piggybacked on `scores` frames and fan
//! out to the other workers on subsequent `eval` frames.  This bench
//! drives duplicate-heavy batches (the same distinct pool, round after
//! round) straight through a [`RemoteBackend`] — no coordinator-side
//! cache in front — so every repeat reaches the fleet, and compares the
//! fabric against a no-gossip baseline where each worker only ever dedups
//! against its own history.
//!
//! The home-worker rotation between batches means a repeated spec usually
//! lands on a worker that did NOT compute it last round: without gossip
//! that is a re-simulation, with gossip the piggybacked deltas are merged
//! before the worker probes its cache, so it is a hit.  The gate pins the
//! headline claim: at a 4-worker fleet, gossip cuts duplicated compute by
//! at least 70% relative to the no-gossip baseline (in practice the
//! fabric eliminates it: fleet misses == distinct specs, exactly).
//!
//!   cargo bench --bench remote_fabric
//!   AVO_BENCH_QUICK=1 cargo bench --bench remote_fabric   # CI-sized
//!
//! Workers are hosted on threads via [`serve`] (same protocol code as
//! `avo eval-worker`, minus process spawning) so the bench measures the
//! fabric, not fork/exec.

use std::collections::HashSet;
use std::net::TcpListener;
use std::sync::atomic::Ordering;
use std::time::Instant;

use avo::benchkit::Bench;
use avo::eval::remote::{serve, WorkerOptions};
use avo::eval::{RemoteBackend, RemoteTopology};
use avo::kernelspec::KernelSpec;
use avo::score::Evaluator;
use avo::EvalBackend;

struct Sizing {
    /// Distinct specs in the duplicate-heavy pool.
    distinct: usize,
    /// Times the full pool is re-dispatched (round 1 is the cold fill).
    rounds: usize,
}

fn sizing() -> Sizing {
    if std::env::var("AVO_BENCH_QUICK").is_ok() {
        Sizing { distinct: 8, rounds: 3 }
    } else {
        Sizing { distinct: 12, rounds: 5 }
    }
}

/// `n` specs with pairwise-distinct content hashes: the baselines plus
/// block-shape variants of the naive genome.
fn distinct_pool(n: usize) -> Vec<KernelSpec> {
    let mut seen = HashSet::new();
    let mut pool = Vec::new();
    for spec in [
        KernelSpec::naive(),
        avo::baselines::fa4_genome(),
        avo::baselines::cudnn_genome(),
        avo::baselines::evolved_genome(),
    ] {
        if pool.len() < n && seen.insert(spec.content_hash()) {
            pool.push(spec);
        }
    }
    let blocks: [u32; 6] = [8, 16, 32, 64, 128, 256];
    let mut i = 0;
    while pool.len() < n {
        let mut s = KernelSpec::naive();
        s.block_q = blocks[i % blocks.len()];
        s.block_k = blocks[(i / blocks.len()) % blocks.len()];
        i += 1;
        if seen.insert(s.content_hash()) {
            pool.push(s);
        }
    }
    pool
}

/// Bind `n` thread-hosted workers and return their endpoints plus the
/// join handles (each serves exactly one connection, the backend's).
fn host_fleet(n: usize) -> (Vec<String>, Vec<std::thread::JoinHandle<()>>) {
    let mut addrs = Vec::new();
    let mut handles = Vec::new();
    for _ in 0..n {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind worker");
        addrs.push(listener.local_addr().expect("local addr").to_string());
        handles.push(std::thread::spawn(move || {
            let workload = avo::workload::parse("mha").expect("workload");
            let eval = Evaluator::for_workload(&*workload);
            let opts = WorkerOptions { once: true, eval_workers: 2, ..WorkerOptions::default() };
            serve(listener, &eval, &opts).expect("serve");
        }));
    }
    (addrs, handles)
}

struct FabricRun {
    /// Specs the fleet actually simulated (cold fill included).
    misses: u64,
    /// Specs served from a worker cache instead of re-simulated.
    saved: u64,
    /// Warm-round throughput, specs per second (rounds 2..N).
    warm_specs_per_sec: f64,
}

impl FabricRun {
    /// Fraction of the avoidable duplicate dispatches (everything beyond
    /// the first copy of each distinct spec) that was re-simulated.
    fn duplicated_fraction(&self, distinct: u64) -> f64 {
        let total = self.misses + self.saved;
        let avoidable = total - distinct;
        if avoidable == 0 {
            return 0.0;
        }
        (self.misses - distinct) as f64 / avoidable as f64
    }
}

fn run_fleet(workers: usize, gossip: bool) -> FabricRun {
    let s = sizing();
    let pool = distinct_pool(s.distinct);
    let (addrs, handles) = host_fleet(workers);
    let workload = avo::workload::parse("mha").expect("workload");
    let eval = Evaluator::for_workload(&*workload);
    let topo = RemoteTopology { connect: addrs, gossip, ..RemoteTopology::default() };
    let backend = RemoteBackend::from_topology(eval, "mha", &topo).expect("attach fleet");

    backend.evaluate_batch(&pool); // cold fill
    let warm = Instant::now();
    for _ in 1..s.rounds {
        backend.evaluate_batch(&pool);
    }
    let warm_elapsed = warm.elapsed();

    let stats = backend.stats();
    let misses = stats.fleet_misses.load(Ordering::SeqCst);
    let saved = stats.dedup_saved.load(Ordering::SeqCst);
    // Every dispatched spec is accounted exactly once by the worker-side
    // hit/miss counters.
    assert_eq!(
        misses + saved,
        (s.rounds * pool.len()) as u64,
        "fleet hit/miss accounting lost specs"
    );
    drop(backend);
    for h in handles {
        h.join().expect("worker thread");
    }
    let warm_specs = ((s.rounds - 1) * pool.len()) as f64;
    FabricRun {
        misses,
        saved,
        warm_specs_per_sec: warm_specs / warm_elapsed.as_secs_f64().max(1e-9),
    }
}

fn main() {
    let s = sizing();
    let distinct = distinct_pool(s.distinct).len() as u64;

    let mut b = Bench::new("remote_fabric").with_iters(1, 2);
    for workers in [1usize, 2, 4] {
        b.case(&format!("warm_fleet_{workers}w_gossip"), move || run_fleet(workers, true));
    }
    b.finish();

    println!("\n== eval-cache fabric: duplicate-heavy batches, {distinct} distinct specs ==");
    let mut gate: Option<(f64, f64)> = None;
    for workers in [1usize, 2, 4] {
        let gossiped = run_fleet(workers, true);
        let isolated = run_fleet(workers, false);
        let g_frac = gossiped.duplicated_fraction(distinct);
        let i_frac = isolated.duplicated_fraction(distinct);
        println!(
            "  {workers} worker(s): gossip {:5.1}% duplicated ({} sims, {} saved, \
             {:6.0} specs/s warm)  |  no-gossip {:5.1}% duplicated ({} sims, {} saved)",
            100.0 * g_frac,
            gossiped.misses,
            gossiped.saved,
            gossiped.warm_specs_per_sec,
            100.0 * i_frac,
            isolated.misses,
            isolated.saved,
        );
        // The fabric's determinism-backed invariant: merge-before-probe
        // means a spec computed anywhere in the fleet is never simulated
        // again, whichever worker later rounds land on.
        assert_eq!(
            gossiped.misses, distinct,
            "{workers}-worker gossip fleet re-simulated a known spec"
        );
        if workers == 4 {
            gate = Some((g_frac, i_frac));
        }
    }

    // The PR gate: at 4 workers, gossip must cut duplicated compute by
    // >= 70% relative to the per-worker-cache-only baseline.
    let (g_frac, i_frac) = gate.expect("4-worker leg ran");
    assert!(
        i_frac > 0.0,
        "no-gossip baseline re-simulated nothing; home rotation should \
         have moved repeats across the fleet"
    );
    let cut = 1.0 - g_frac / i_frac;
    println!("  duplicated-compute cut at 4 workers: {:.0}%", 100.0 * cut);
    assert!(
        cut >= 0.70,
        "gossip cut duplicated compute by {:.0}% (< 70%): {:.1}% vs {:.1}%",
        100.0 * cut,
        100.0 * g_frac,
        100.0 * i_frac,
    );
}
