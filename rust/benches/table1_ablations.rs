//! Bench for Table 1: times the before/after simulation of each named
//! optimization (the ablation measurement path), then prints the table.

use avo::baselines::ablations;
use avo::benchkit::Bench;
use avo::repro;
use avo::score::{mha_suite, Evaluator};

fn main() {
    let eval = Evaluator::new(mha_suite());
    let mut b = Bench::new("table1_ablations");
    for (name, (before, after)) in [
        ("branchless_rescale", ablations::branchless_rescale()),
        ("correction_overlap", ablations::correction_overlap()),
        ("register_rebalance", ablations::register_rebalance()),
    ] {
        b.case(&format!("{name}/before"), || eval.evaluate(&before));
        b.case(&format!("{name}/after"), || eval.evaluate(&after));
    }
    b.finish();
    println!("\n{}", repro::table1());
}
