//! Bench for Figures 5/6: times the full seeded evolution run (the 7-day
//! analog) and the trajectory extraction, then prints both figures.
//! AVO_BENCH_QUICK=1 shortens the timing loop (the run itself is seconds).

use avo::benchkit::Bench;
use avo::coordinator::EvolutionDriver;
use avo::repro;

fn main() {
    let mut b = Bench::new("fig5_trajectory").with_iters(0, 3);
    b.case("paper_run_40_commits", || {
        EvolutionDriver::new(repro::paper_run_config()).run()
    });
    let report = repro::paper_run();
    b.case("trajectory_extract", || {
        (report.lineage.trajectory(true), report.lineage.trajectory(false))
    });
    b.finish();
    println!("\n{}", repro::fig56(&report, true));
    println!("{}", repro::fig56(&report, false));
    println!("{}", repro::stats(&report));
}
