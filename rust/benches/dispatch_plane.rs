//! Fleet-wide dispatch plane vs per-island dispatch under a skewed
//! remote fleet ([`avo::eval::DispatchPlane`]).
//!
//! Eight steady-state islands drive a 4-worker TCP fleet in which one
//! worker is a 4x latency straggler (each worker hosts a
//! `Cached<Skew<Sim>>` stack behind the real wire protocol via
//! [`serve_with`]).  Without the plane, every island submits its own
//! narrow lookahead batch: after the coordinator cache, at most 8
//! distinct specs reach the work-stealing queue at a time, so the
//! oversplitter (live x 4 slots) can only cut width-1 chunks and every
//! spec pays a full round-trip of per-frame latency.  With
//! `--dispatch-plane`, cross-island submissions coalesce into one
//! full-width batch before the stack, the queue sees dozens of pending
//! specs at once, and chunks widen — fewer round trips over the same
//! straggler fleet.
//!
//! The gates pin the PR's headline claims at 8 islands x 4 workers:
//!
//! * mean remote chunk width (`remote_chunk_specs /
//!   remote_chunks_dispatched`) at least doubles vs the plane-off
//!   baseline (which is pinned at exactly 1.0 by the width math above);
//! * wall-clock drops by at least 25%.
//!
//!   cargo bench --bench dispatch_plane
//!   AVO_BENCH_QUICK=1 cargo bench --bench dispatch_plane   # CI-sized
//!
//! Wall-clock is dominated by the injected per-frame skew delays, so
//! iteration counts stay at 1 x 2.

use std::net::TcpListener;
use std::time::{Duration, Instant};

use avo::benchkit::Bench;
use avo::coordinator::{RunConfig, RunReport, SchedulingMode};
use avo::eval::remote::{serve_with, WorkerOptions};
use avo::eval::{CachedBackend, SimBackend, SkewBackend};
use avo::islands::Archipelago;
use avo::score::Evaluator;

const SEED: u64 = 42;
const ISLANDS: usize = 8;
/// One latency multiplier per fleet worker: a 4x straggler plus three
/// 1x workers.  Each worker thread hosts its own single-entry table, so
/// the one connection-handler thread it serves is bound to that slot.
const FLEET_SKEW: [u32; 4] = [4, 1, 1, 1];

struct Sizing {
    commits: usize,
    steps: usize,
    delay_ms: u64,
}

fn sizing() -> Sizing {
    if std::env::var("AVO_BENCH_QUICK").is_ok() {
        Sizing { commits: 3, steps: 14, delay_ms: 2 }
    } else {
        Sizing { commits: 6, steps: 30, delay_ms: 3 }
    }
}

/// Bind one thread-hosted worker per skew multiplier and return the
/// endpoints plus join handles (each serves exactly one connection).
fn host_skewed_fleet(delay: Duration) -> (Vec<String>, Vec<std::thread::JoinHandle<()>>) {
    let mut addrs = Vec::new();
    let mut handles = Vec::new();
    for mult in FLEET_SKEW {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind worker");
        addrs.push(listener.local_addr().expect("local addr").to_string());
        handles.push(std::thread::spawn(move || {
            let workload = avo::workload::parse("mha").expect("workload");
            let eval = Evaluator::for_workload(&*workload);
            let backend = CachedBackend::new(SkewBackend::new(
                SimBackend::new(eval, 1),
                delay,
                vec![mult],
            ));
            let opts = WorkerOptions { once: true, eval_workers: 1, ..WorkerOptions::default() };
            serve_with(listener, &backend, &opts).expect("serve");
        }));
    }
    (addrs, handles)
}

struct PlaneRun {
    report: RunReport,
    wall: Duration,
}

impl PlaneRun {
    /// Mean specs per remote chunk over the whole run.
    fn mean_chunk_width(&self) -> f64 {
        let chunks = self.report.metrics.counter("remote_chunks_dispatched");
        assert!(chunks > 0, "run dispatched no remote chunks");
        self.report.metrics.counter("remote_chunk_specs") as f64 / chunks as f64
    }
}

fn run_case(plane: bool) -> PlaneRun {
    let s = sizing();
    let (addrs, handles) = host_skewed_fleet(Duration::from_millis(s.delay_ms));
    let mut cfg = RunConfig {
        seed: SEED,
        target_commits: s.commits,
        max_steps: s.steps,
        ..RunConfig::default()
    };
    cfg.topology.islands = ISLANDS;
    cfg.topology.workers = ISLANDS;
    cfg.topology.migrate_every = 2;
    cfg.topology.scheduling = SchedulingMode::SteadyState;
    cfg.topology.dispatch_plane = plane;
    cfg.topology.coalesce_window_evals = 64;
    cfg.topology.remote.connect = addrs;
    // Wide per-direction candidate batches: the raw material the plane
    // coalesces (and the baseline dispatches island-by-island).
    cfg.agent.lookahead = 8;
    let workload = cfg.workload();
    let started = Instant::now();
    let report = Archipelago::new(cfg).run_from(workload.seed_genome(), &workload.seed_message());
    let wall = started.elapsed();
    for h in handles {
        h.join().expect("worker thread");
    }
    PlaneRun { report, wall }
}

fn main() {
    let mut b = Bench::new("dispatch_plane").with_iters(1, 2);
    b.case("steady_8i_4w_skew_direct", || run_case(false));
    b.case("steady_8i_4w_skew_plane", || run_case(true));
    b.finish();

    let direct = run_case(false);
    let plane = run_case(true);

    println!("\n== dispatch plane: {ISLANDS} islands over a 4-worker skewed fleet ==");
    for (name, run) in [("direct", &direct), ("plane", &plane)] {
        println!(
            "  {name:<7} wall {:7.1} ms | chunks {:4} mean width {:4.2} | coalesced batches {:3} (mean {:4.1} specs)",
            run.wall.as_secs_f64() * 1e3,
            run.report.metrics.counter("remote_chunks_dispatched"),
            run.mean_chunk_width(),
            run.report.metrics.counter("dispatch_batches"),
            {
                let batches = run.report.metrics.counter("dispatch_batches");
                if batches > 0 {
                    run.report.metrics.counter("dispatch_coalesced_specs") as f64 / batches as f64
                } else {
                    0.0
                }
            },
        );
        println!("    {}", run.report.summary());
    }

    // Sanity: the plane actually engaged (and only when asked).
    assert_eq!(direct.report.metrics.counter("dispatch_batches"), 0);
    assert!(plane.report.metrics.counter("dispatch_batches") > 0);

    // Gate 1: coalescing must at least double the mean remote chunk
    // width.  Per-island batches (<= 8 distinct misses at a time) can
    // never exceed width 1.0 against the live x 4 oversplitter, so this
    // is a true 2x.
    let widen = plane.mean_chunk_width() / direct.mean_chunk_width();
    println!("  chunk-width ratio plane/direct: {widen:.2}x");
    assert!(
        widen >= 2.0,
        "plane widened remote chunks only {widen:.2}x (< 2x): {:.2} vs {:.2}",
        plane.mean_chunk_width(),
        direct.mean_chunk_width(),
    );

    // Gate 2: fewer, wider round trips over the straggler fleet must cut
    // wall-clock by >= 25%.
    let cut = 1.0 - plane.wall.as_secs_f64() / direct.wall.as_secs_f64();
    println!("  wall-clock cut: {:.0}%", 100.0 * cut);
    assert!(
        cut >= 0.25,
        "plane cut wall-clock by {:.0}% (< 25%): {:.1} ms vs {:.1} ms",
        100.0 * cut,
        plane.wall.as_secs_f64() * 1e3,
        direct.wall.as_secs_f64() * 1e3,
    );
}
