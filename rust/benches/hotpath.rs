//! Hot-path microbenchmarks (the §Perf targets in EXPERIMENTS.md):
//! the scoring function decomposed — structural validation, functional
//! correctness execution, cycle model, full suite evaluation, batched
//! backend throughput — plus store/json costs.
//!
//! Doubles as the CI batching smoke: after timing, it asserts that the
//! batched eval path (parallel SimBackend, cached batch with in-batch
//! dedup) returns score-identical results to one-at-a-time evaluation,
//! and that the cached batch actually deduplicates.  A batching
//! regression fails the build, not just the numbers.

use avo::baselines;
use avo::benchkit::Bench;
use avo::coordinator::EvalPool;
use avo::eval::{CachedBackend, EvalBackend, SimBackend};
use avo::json::ToJson;
use avo::kernelspec::KernelSpec;
use avo::score::{mha_suite, BenchConfig, Evaluator, Score};
use avo::sim::{functional, machine::MachineSpec, pipeline};

fn main() {
    let eval = Evaluator::new(mha_suite());
    let spec = baselines::evolved_genome();
    let m = MachineSpec::b200();
    let cfg = BenchConfig::mha(1, 32768, true);

    let mut b = Bench::new("hotpath").with_iters(3, 30);
    b.case("validate", || spec.validate());
    b.case("functional_check", || functional::check(&spec, true, 1, 1));
    b.case("cycle_model_one_cell", || pipeline::simulate(&spec, &cfg, &m));
    b.case("suite_evaluate_full", || eval.evaluate(&spec));
    b.case("profile_report", || {
        avo::sim::profile::profile(&pipeline::simulate(&spec, &cfg, &m))
    });
    b.case("spec_json_roundtrip", || {
        let j = spec.to_json().compact();
        avo::json::parse(&j).unwrap()
    });
    b.case("content_hash", || spec.content_hash());

    // 64 genomes over 4 distinct pipeline depths: 16-way duplication, the
    // shape an archipelago's convergent proposals actually have.
    let specs: Vec<KernelSpec> = (0..64)
        .map(|i| {
            let mut s = baselines::evolved_genome();
            s.kv_pipeline_depth = 1 + (i % 4) as u32;
            s
        })
        .collect();
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let pool = EvalPool::new(workers);
    b.case("pool_batch_64", || pool.evaluate_batch(&eval, &specs));
    let seq = EvalPool::new(1);
    b.case("seq_batch_64", || seq.evaluate_batch(&eval, &specs));

    let sim = SimBackend::new(eval.clone(), workers);
    b.case("backend_batch_64", || sim.evaluate_batch(&specs));
    b.case("backend_one_at_a_time_64", || {
        specs.iter().map(|s| sim.evaluate(s)).collect::<Vec<Score>>()
    });
    // Fresh cache per iteration: times the dedup fill (4 computations for
    // 64 requests), not warm hits.
    b.case("cached_backend_batch_64_cold", || {
        CachedBackend::new(SimBackend::new(eval.clone(), workers)).evaluate_batch(&specs)
    });
    let warm = CachedBackend::new(SimBackend::new(eval.clone(), workers));
    warm.evaluate_batch(&specs);
    b.case("cached_backend_batch_64_warm", || warm.evaluate_batch(&specs));
    b.finish();

    // == batching smoke (CI gate) ==
    let batched = sim.evaluate_batch(&specs);
    let one_at_a_time: Vec<Score> = specs.iter().map(|s| eval.evaluate(s)).collect();
    assert_eq!(batched.len(), one_at_a_time.len());
    for (i, (a, b)) in batched.iter().zip(&one_at_a_time).enumerate() {
        assert_eq!(
            a.per_config, b.per_config,
            "batched eval diverged from one-at-a-time at index {i}"
        );
    }
    let cached = CachedBackend::new(SimBackend::new(eval.clone(), workers));
    let via_cache = cached.evaluate_batch(&specs);
    for (i, (a, b)) in via_cache.iter().zip(&one_at_a_time).enumerate() {
        assert_eq!(
            a.per_config, b.per_config,
            "cached batch diverged from one-at-a-time at index {i}"
        );
    }
    let stats = cached.cache_stats();
    assert_eq!(
        stats.hits + stats.misses,
        specs.len() as u64,
        "every batch slot must count as exactly one hit or miss"
    );
    assert_eq!(stats.misses, 4, "64 specs over 4 distinct genomes must compute 4");
    println!(
        "batching smoke OK: 64-spec batch, {} dedup hits / {} computations",
        stats.hits, stats.misses
    );
}
