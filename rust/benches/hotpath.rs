//! Hot-path microbenchmarks (the §Perf targets in EXPERIMENTS.md):
//! the scoring function decomposed — structural validation, functional
//! correctness execution, cycle model, full suite evaluation, parallel
//! batch throughput — plus store/json costs.

use avo::baselines;
use avo::benchkit::Bench;
use avo::coordinator::EvalPool;
use avo::json::ToJson;
use avo::kernelspec::KernelSpec;
use avo::score::{mha_suite, BenchConfig, Evaluator};
use avo::sim::{functional, machine::MachineSpec, pipeline};

fn main() {
    let eval = Evaluator::new(mha_suite());
    let spec = baselines::evolved_genome();
    let m = MachineSpec::b200();
    let cfg = BenchConfig::mha(1, 32768, true);

    let mut b = Bench::new("hotpath").with_iters(3, 30);
    b.case("validate", || spec.validate());
    b.case("functional_check", || functional::check(&spec, true, 1, 1));
    b.case("cycle_model_one_cell", || pipeline::simulate(&spec, &cfg, &m));
    b.case("suite_evaluate_full", || eval.evaluate(&spec));
    b.case("profile_report", || {
        avo::sim::profile::profile(&pipeline::simulate(&spec, &cfg, &m))
    });
    b.case("spec_json_roundtrip", || {
        let j = spec.to_json().compact();
        avo::json::parse(&j).unwrap()
    });
    b.case("content_hash", || spec.content_hash());

    let specs: Vec<KernelSpec> = (0..64)
        .map(|i| {
            let mut s = baselines::evolved_genome();
            s.kv_pipeline_depth = 1 + (i % 4) as u32;
            s
        })
        .collect();
    let pool = EvalPool::new(
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
    );
    b.case("pool_batch_64", || pool.evaluate_batch(&eval, &specs));
    let seq = EvalPool::new(1);
    b.case("seq_batch_64", || seq.evaluate_batch(&eval, &specs));
    b.finish();
}
